//! Bounded per-instance shard queues (DESIGN.md S11.2, S22).
//!
//! The serving path used to funnel every request through one global
//! `Mutex<VecDeque>`; under many instances the single lock and condvar
//! become the scaling bottleneck. A [`ShardQueue`] is owned by exactly one
//! worker (its *home* shard) and bounded individually, so submit-side
//! backpressure and wakeups touch one shard instead of a global lock.
//! Idle workers may *steal* from sibling shards (`claim_batch` in
//! `coordinator::node`) which keeps tail latency flat when the
//! dispatcher's load estimate lags reality. Stealing — like the shards
//! themselves — is strictly node-local in a multi-node fleet (DESIGN.md
//! S21): cross-node movement of queued work happens only through a
//! migration's drain + re-dispatch.
//!
//! # Lock-free core (DESIGN.md S22)
//!
//! The hot submit path is **lock-free**: producers enqueue into a bounded
//! MPMC ring of sequence-stamped slots (Vyukov's scheme — claim a
//! position with one CAS, publish the payload with one release-store).
//! `try_push` therefore costs two atomic RMWs and no lock, which is what
//! `perf_coordinator`'s µs/req-at-8-instances gate measures.
//!
//! The consumer side keeps the *exact* deque semantics the model-based
//! property tests in `tests/sim_properties.rs` pin (FIFO front pops,
//! back-of-queue stealing, full drains, a depth mirror that is exact
//! between operations): consumers serialize on a small **staging** deque
//! — the logical queue is `staging ++ ring` — and *reap* completed ring
//! slots into it before operating. Reaping preserves ring order, so FIFO
//! and per-producer order survive; only consumers contend on the staging
//! lock, never submitters.
//!
//! `push_unbounded` (the Central Controller's drain/re-dispatch path) may
//! exceed both the logical capacity and the physical ring: it overflows
//! into staging *after* reaping every position claimed before it, which
//! keeps per-producer FIFO intact across the spill.
//!
//! For the elastic capacity manager (DESIGN.md S6.1) a shard can be
//! **gated**: dispatchers and stealing skip it, its worker parks on the
//! shard's wait slot ([`ShardQueue::park_while_gated`]) until scale-up or
//! shutdown wakes it, and the Central Controller drains whatever was
//! queued into the still-active shards each epoch.
//!
//! Every blocking wait goes through the shard's injected
//! [`Clock`](crate::clock::Clock) (DESIGN.md S18): under `WallClock` the
//! behavior is the classic timed condvar wait; under `VirtualClock` the
//! worker parks in simulation time, so a whole serving run is
//! deterministic. Lost wakeups are prevented by the slot's generation
//! counter — the waiter samples it *before* re-checking the queue, and a
//! notify that lands in between makes the wait return immediately. As a
//! second guard, `pop_wait` drains the queue once more *after* its
//! deadline passes: a push landing between the empty re-check and the
//! deadline comparison is returned instead of stranded.
//!
//! # Verification (DESIGN.md S23)
//!
//! Every synchronization primitive here is imported through [`crate::sync`]
//! so `tests/loom_models.rs` (built with `RUSTFLAGS="--cfg loom"`, run via
//! `make loom`) can exhaustively model-check the ring: the exact capacity
//! bound, per-producer FIFO across `overflow_push` reaping, the `WaitSlot`
//! generation protocol, and gate/drain vs. push conservation. The
//! `Ordering::*` choice at every atomic site is justified in the DESIGN.md
//! S23 table; `// SAFETY:` comments on the four unsafe sites below are the
//! audited exclusivity arguments, and under `cfg(loom)` the shim's
//! `UnsafeCell` turns any violation of them into a model failure.

#![deny(unsafe_op_in_unsafe_fn)]

use std::collections::VecDeque;
use std::time::Duration;

use crate::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::sync::cell::UnsafeCell;
use crate::sync::{Arc, Mutex, MutexGuard};

use crate::clock::{self, Clock, WaitSlot};

use super::Request;

/// Physical ring sizes are capped so a huge configured capacity cannot
/// balloon the slot array; pushes beyond the ring spill into staging.
const MAX_RING_SLOTS: usize = 1 << 16;

/// One slot of the lock-free ring. `seq` encodes the slot's lap state
/// (Vyukov MPMC): equal to the position when free for a producer, to
/// `position + 1` when a payload is published, and to `position + size`
/// once the reaper has emptied it for the next lap.
struct Slot {
    seq: AtomicUsize,
    val: UnsafeCell<Option<Request>>,
}

/// Bounded lock-free MPMC ring: producers are fully lock-free; slots are
/// emptied only by the single reaper (the consumer holding the shard's
/// staging lock), in position order, so ring order is FIFO.
struct Ring {
    buf: Box<[Slot]>,
    mask: usize,
    /// Next position a producer claims (CAS).
    enqueue_pos: AtomicUsize,
    /// Next position the reaper consumes. Written only under the staging
    /// lock; atomic so overflowing producers can snapshot progress.
    dequeue_pos: AtomicUsize,
}

// SAFETY (audited, unsafe sites 1 & 2 of 4 — DESIGN.md S23): `val` is
// written by exactly one producer — the winner of the `enqueue_pos` CAS
// for that position — strictly before its release-store of `seq`, and read
// by exactly one reaper — the consumer holding the staging lock — strictly
// after an acquire-load observes that store. The slot is not reused until
// the reaper's own release-store of the next-lap `seq` value, which the
// next producer acquire-loads. No two threads ever access a `val`
// concurrently; under `cfg(loom)` the shim `UnsafeCell`'s access-window
// tracking enforces exactly this claim across every explored interleaving.
unsafe impl Sync for Ring {}
// SAFETY: as above — `Request` itself is `Send`, and slot payloads move
// between threads only through the published-slot protocol.
unsafe impl Send for Ring {}

impl Ring {
    fn new(capacity: usize) -> Self {
        // At least 2 slots: in a 1-slot ring the sequence value a producer
        // publishes at position `p` (`p + 1`) is the same value that marks
        // the slot free for position `p + size == p + 1`, so a second
        // unbounded push racing ahead of the reaper would claim the slot
        // and overwrite the unconsumed request — and the reaper, waiting
        // for a sequence that can no longer appear, would spin forever.
        // Found by the loom model
        // `per_producer_fifo_survives_overflow_reaping` at capacity 1
        // (DESIGN.md S23). Two slots restore the Vyukov invariant that
        // "published" (`p + 1`) and "free next lap" (`p + size`) are
        // distinct values.
        let size = capacity.next_power_of_two().max(2).min(MAX_RING_SLOTS);
        let buf: Box<[Slot]> = (0..size)
            .map(|i| Slot { seq: AtomicUsize::new(i), val: UnsafeCell::new(None) })
            .collect();
        Ring {
            mask: size - 1,
            buf,
            enqueue_pos: AtomicUsize::new(0),
            dequeue_pos: AtomicUsize::new(0),
        }
    }

    /// Lock-free push; hands the request back when the ring is physically
    /// full (one whole lap of unconsumed slots).
    fn push(&self, r: Request) -> Result<(), Request> {
        let mut pos = self.enqueue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq.wrapping_sub(pos) as isize;
            if diff == 0 {
                match self.enqueue_pos.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY (unsafe site 3 of 4): winning the CAS
                        // gives this thread exclusive write access to the
                        // slot until the release-store of `seq` publishes
                        // it (see the `unsafe impl Sync` contract), so no
                        // other access window can overlap this write.
                        slot.val.with_mut(|p| unsafe { *p = Some(r) });
                        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
                        return Ok(());
                    }
                    Err(p) => pos = p,
                }
            } else if diff < 0 {
                return Err(r);
            } else {
                pos = self.enqueue_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Reap the oldest published item, if any. FIFO: stops (returns
    /// `None`) at a claimed-but-unpublished slot rather than skipping it.
    /// Caller must hold the shard's staging lock (single reaper).
    fn reap_one(&self) -> Option<Request> {
        let pos = self.dequeue_pos.load(Ordering::Relaxed);
        let slot = &self.buf[pos & self.mask];
        let seq = slot.seq.load(Ordering::Acquire);
        if seq.wrapping_sub(pos.wrapping_add(1)) as isize == 0 {
            // SAFETY (unsafe site 4 of 4): `seq == pos + 1` happens-after
            // the producer's release-store, so the payload is fully
            // written and ours to take; the staging lock excludes any
            // other reaper, so this is the only live access window.
            let r = slot.val.with_mut(|p| unsafe { (*p).take() });
            slot.seq
                .store(pos.wrapping_add(self.buf.len()), Ordering::Release);
            self.dequeue_pos.store(pos.wrapping_add(1), Ordering::Relaxed);
            r
        } else {
            None
        }
    }

    /// Current producer frontier (positions before it are claimed).
    ///
    /// Relaxed (was Acquire; S23): `enqueue_pos` is only ever mutated by
    /// Relaxed CASes, so an Acquire load here paired with no release and
    /// ordered nothing. The value is used purely as a reap-target bound —
    /// payload visibility is carried by each slot's `seq` acquire in
    /// `reap_one` — and the caller's *own* prior claims are visible by
    /// same-thread coherence. Covered by the loom model
    /// `per_producer_fifo_survives_overflow_reaping`.
    fn claimed_frontier(&self) -> usize {
        self.enqueue_pos.load(Ordering::Relaxed)
    }
}

/// A bounded lock-free request queue owned by one worker instance.
pub struct ShardQueue {
    ring: Ring,
    /// Reaped front of the logical queue plus unbounded overflow; its
    /// mutex doubles as the consumer-side (reaper) serialization lock.
    staging: Mutex<VecDeque<Request>>,
    clock: Arc<dyn Clock>,
    slot: Arc<WaitSlot>,
    /// Exact logical length (staging + ring), maintained push/pop side.
    len: AtomicUsize,
    capacity: usize,
    gated: AtomicBool,
    failed: AtomicBool,
}

impl std::fmt::Debug for ShardQueue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardQueue")
            .field("len", &self.len())
            .field("capacity", &self.capacity)
            .field("gated", &self.is_gated())
            .field("failed", &self.is_failed())
            .finish()
    }
}

impl ShardQueue {
    /// Create a wall-clock shard bounded to `capacity` queued requests
    /// (min 1).
    pub fn new(capacity: usize) -> Self {
        Self::with_clock(capacity, clock::wall())
    }

    /// Create a shard whose blocking waits go through `clock` (the fleet
    /// passes its own clock so `VirtualClock` runs are deterministic).
    pub fn with_clock(capacity: usize, clock: Arc<dyn Clock>) -> Self {
        let slot = clock.new_slot();
        let capacity = capacity.max(1);
        ShardQueue {
            ring: Ring::new(capacity),
            staging: Mutex::new(VecDeque::new()),
            clock,
            slot,
            len: AtomicUsize::new(0),
            capacity,
            gated: AtomicBool::new(false),
            failed: AtomicBool::new(false),
        }
    }

    /// Take the staging (reaper) lock, recovering from poisoning: a
    /// `VecDeque` of requests has no invariant a panicking peer could have
    /// broken, and losing queued requests to a poisoned lock would drop
    /// admitted work.
    fn locked(&self) -> MutexGuard<'_, VecDeque<Request>> {
        match self.staging.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Move every published ring item into staging, in ring (FIFO) order.
    fn reap_all(&self, st: &mut VecDeque<Request>) {
        while let Some(r) = self.ring.reap_one() {
            st.push_back(r);
        }
    }

    /// Reap until every position claimed before `target` has been moved
    /// into staging, spinning through claimed-but-unpublished slots (the
    /// producer is mid-publish; it finishes without needing any lock, so
    /// the spin is bounded and deadlock-free).
    fn reap_until(&self, st: &mut VecDeque<Request>, target: usize) {
        while (target.wrapping_sub(self.ring.dequeue_pos.load(Ordering::Relaxed)) as isize) > 0
        {
            match self.ring.reap_one() {
                Some(r) => st.push_back(r),
                // Under `cfg(loom)` this spin yields to the scheduler so
                // the mid-publish producer can finish (see crate::sync).
                None => crate::sync::hint::spin_loop(),
            }
        }
    }

    /// Spill `r` behind everything claimed in the ring before it: reap up
    /// to the claim frontier, then append to staging. Preserves FIFO and
    /// per-producer order across the overflow (this thread's own earlier
    /// pushes are all before the frontier).
    fn overflow_push(&self, r: Request) {
        let target = self.ring.claimed_frontier();
        let mut st = self.locked();
        self.reap_until(&mut st, target);
        st.push_back(r);
    }

    /// Take up to `max` requests from the front. Returns the items and
    /// whether the queue held *any* published item (so `pop_wait` can
    /// distinguish "empty queue" from a zero-`max` call).
    fn take_front(&self, max: usize) -> (Vec<Request>, bool) {
        let mut st = self.locked();
        // Top up staging so the front `max` items (at least one, for the
        // emptiness probe) are present in deque form.
        while st.len() < max.max(1) {
            match self.ring.reap_one() {
                Some(r) => st.push_back(r),
                None => break,
            }
        }
        let nonempty = !st.is_empty();
        let n = st.len().min(max);
        let out: Vec<Request> = st.drain(..n).collect();
        if n > 0 {
            // Relaxed (was AcqRel; S23): `len` is a pure counter — the
            // capacity bound needs only the atomic's total modification
            // order, and no payload is published through it (slot `seq`
            // and the staging mutex carry data visibility). Covered by
            // loom models `bounded_push_never_over_admits` and
            // `gate_drain_vs_push_never_drops`.
            self.len.fetch_sub(n, Ordering::Relaxed);
        }
        (out, nonempty)
    }

    /// Maximum number of queued requests before pushes are refused.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Lock-free depth mirror (exact between operations).
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }

    /// True when the shard currently holds no requests.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when the elastic capacity manager has gated this shard's
    /// instance (dispatch and stealing skip it; its worker is parked).
    pub fn is_gated(&self) -> bool {
        // Acquire/Release (was SeqCst; S23): the flag needs no total
        // order against other atomics — a stale `true` read is resolved
        // by the `WaitSlot` generation protocol (the worker samples the
        // generation *before* re-checking the flag, and `set_gated`'s
        // notify moves it), and a stale `false` read only delays a skip
        // decision one dispatch round. Covered by loom models
        // `waitslot_generation_has_no_lost_wakeups` and
        // `gate_drain_vs_push_never_drops`.
        self.gated.load(Ordering::Acquire)
    }

    /// Gate or ungate the shard. Ungating wakes the parked worker; the
    /// slot's generation counter makes the wakeup race-free — a worker
    /// that read the gated flag just before this call sees a moved
    /// generation and returns from its wait immediately.
    pub fn set_gated(&self, gated: bool) {
        self.gated.store(gated, Ordering::Release);
        if !gated {
            self.clock.notify_slot(&self.slot);
        }
    }

    /// True when the fault-injection layer marked this shard's board as
    /// failed (DESIGN.md S20). Informational: the Central Controller
    /// *also* gates a failed shard, so dispatch, stealing and the worker
    /// park all flow through the existing gating machinery — this flag
    /// only distinguishes "down" from "scaled down" in stats and reports.
    pub fn is_failed(&self) -> bool {
        // Acquire/Release (was SeqCst; S23): informational flag — the CC
        // is the only writer and every consumer tolerates one-epoch
        // staleness (gating, not this flag, stops dispatch).
        self.failed.load(Ordering::Acquire)
    }

    /// Mark the shard's board failed/recovered (set by the CC at epoch
    /// boundaries from the active `FaultPlan`, cleared on shutdown).
    pub fn set_failed(&self, failed: bool) {
        self.failed.store(failed, Ordering::Release);
    }

    /// Park the calling worker while the shard is gated; returns when
    /// ungated, woken (shutdown), or after `timeout` so the caller can
    /// re-check its stop flag.
    pub fn park_while_gated(&self, timeout: Duration) {
        // Sample the generation before the flag check (lost-wakeup guard).
        let observed = self.slot.generation();
        if !self.is_gated() {
            return;
        }
        self.clock.wait_slot(&self.slot, observed, timeout);
    }

    /// Enqueue a request; on a full shard the request is handed back so
    /// the dispatcher can retry elsewhere or reject (backpressure).
    /// Lock-free: one CAS on the length guard, one CAS on the ring
    /// position (the staging spill runs only when an unbounded backlog
    /// already exceeds the physical ring).
    pub fn try_push(&self, r: Request) -> Result<(), Request> {
        let mut len = self.len.load(Ordering::Relaxed);
        loop {
            if len >= self.capacity {
                return Err(r);
            }
            // Relaxed success (was AcqRel; S23): see `take_front` — the
            // counter's modification order alone enforces the bound; loom
            // model `bounded_push_never_over_admits` explores every
            // push/pop race at the exact-capacity edge.
            match self.len.compare_exchange_weak(
                len,
                len + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(l) => len = l,
            }
        }
        if let Err(r) = self.ring.push(r) {
            // The ring is physically full (an unbounded backlog, or a
            // capacity above the slot cap): spill in order instead of
            // refusing work the length guard already admitted.
            self.overflow_push(r);
        }
        self.clock.notify_slot(&self.slot);
        Ok(())
    }

    /// Enqueue ignoring the capacity bound. Only the Central Controller's
    /// drain/re-dispatch path uses this: a request that was *already
    /// admitted* must never be dropped, even if every shard it could move
    /// to filled up concurrently.
    pub fn push_unbounded(&self, r: Request) {
        self.len.fetch_add(1, Ordering::Relaxed);
        if let Err(r) = self.ring.push(r) {
            self.overflow_push(r);
        }
        self.clock.notify_slot(&self.slot);
    }

    /// Dequeue up to `max` requests without blocking.
    pub fn pop_upto(&self, max: usize) -> Vec<Request> {
        self.take_front(max).0
    }

    /// Dequeue up to `max` requests, waiting up to `wait` for the first
    /// one to arrive. Returns empty only once `wait` has fully elapsed on
    /// the shard's clock with nothing queued — including a final drain at
    /// the deadline, so a push landing between the empty re-check and the
    /// deadline comparison is returned, not stranded (its notify
    /// generation was already consumed by this waiter).
    pub fn pop_wait(&self, max: usize, wait: Duration) -> Vec<Request> {
        let deadline = self.clock.now().saturating_add(clock::ticks(wait));
        loop {
            let observed = self.slot.generation();
            let (out, nonempty) = self.take_front(max);
            if nonempty {
                return out;
            }
            let now = self.clock.now();
            if now >= deadline {
                // Final drain: the deadline check above is outside the
                // staging lock, so a push may have landed since the
                // take_front that found the queue empty.
                return self.take_front(max).0;
            }
            self.clock
                .wait_slot(&self.slot, observed, clock::to_duration(deadline - now));
        }
    }

    /// Take up to `max` requests from the *back* of the queue (work
    /// stealing; the home worker keeps FIFO order at the front).
    pub fn steal_upto(&self, max: usize) -> Vec<Request> {
        let mut st = self.locked();
        self.reap_all(&mut st);
        let n = st.len().min(max);
        let keep = st.len() - n;
        let out: Vec<Request> = st.split_off(keep).into_iter().collect();
        if n > 0 {
            self.len.fetch_sub(n, Ordering::Relaxed);
        }
        out
    }

    /// Drain the whole queue in FIFO order (the CC's gated-shard drain).
    pub fn drain_all(&self) -> Vec<Request> {
        let mut st = self.locked();
        self.reap_all(&mut st);
        let n = st.len();
        let out: Vec<Request> = st.drain(..).collect();
        if n > 0 {
            self.len.fetch_sub(n, Ordering::Relaxed);
        }
        out
    }

    /// Wake every waiter (used on shutdown).
    pub fn wake_all(&self) {
        self.clock.notify_slot(&self.slot);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{ActorScope, Tick, VirtualClock};

    fn req(id: u64) -> Request {
        Request { id, payload: vec![0.0; 4], submitted: 0 }
    }

    #[test]
    fn bounded_push_applies_backpressure() {
        let s = ShardQueue::new(2);
        assert!(s.try_push(req(0)).is_ok());
        assert!(s.try_push(req(1)).is_ok());
        let back = s.try_push(req(2));
        assert!(back.is_err(), "third push must be refused");
        assert_eq!(back.unwrap_err().id, 2, "refused request is handed back");
        assert_eq!(s.len(), 2);
        assert_eq!(s.capacity(), 2);
        // The drain path may exceed the bound so admitted work survives.
        s.push_unbounded(req(3));
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn pop_preserves_fifo_and_depth() {
        let s = ShardQueue::new(16);
        for i in 0..5 {
            s.try_push(req(i)).unwrap();
        }
        assert_eq!(s.len(), 5);
        let a = s.pop_upto(3);
        assert_eq!(a.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(s.len(), 2);
        let b = s.pop_upto(10);
        assert_eq!(b.len(), 2);
        assert!(s.is_empty());
        assert!(s.pop_upto(4).is_empty());
    }

    #[test]
    fn steal_takes_from_the_back() {
        let s = ShardQueue::new(16);
        for i in 0..6 {
            s.try_push(req(i)).unwrap();
        }
        let stolen = s.steal_upto(2);
        assert_eq!(stolen.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4, 5]);
        // Home worker still sees FIFO order at the front.
        let own = s.pop_upto(10);
        assert_eq!(own.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn unbounded_overflow_preserves_fifo_across_ring_and_staging() {
        // Capacity 2 means a 2-slot physical ring: the third and fourth
        // pushes spill through the staging overflow path, and every mixed
        // pop/steal/drain below must still see one FIFO queue.
        let s = ShardQueue::new(2);
        assert!(s.try_push(req(0)).is_ok());
        assert!(s.try_push(req(1)).is_ok());
        s.push_unbounded(req(2));
        s.push_unbounded(req(3));
        assert_eq!(s.len(), 4);
        assert!(s.try_push(req(4)).is_err(), "bound still enforced over the backlog");
        let front = s.pop_upto(2);
        assert_eq!(front.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert!(s.try_push(req(4)).is_err(), "backlog still at capacity");
        let stolen = s.steal_upto(1);
        assert_eq!(stolen.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3]);
        let rest = s.drain_all();
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        assert!(s.is_empty());
    }

    #[test]
    fn pop_wait_returns_queued_work_without_waiting() {
        let s = ShardQueue::new(8);
        s.try_push(req(7)).unwrap();
        // Zero timeout: queued work is still returned immediately.
        let got = s.pop_wait(4, Duration::ZERO);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 7);
        assert!(s.pop_wait(4, Duration::from_millis(5)).is_empty());
    }

    #[test]
    fn pop_wait_virtual_time_wakes_on_push_deterministically() {
        // No real sleeps: the consumer parks in virtual time, the producer
        // pushes 30 virtual ms later, and the wakeup tick is exact.
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _me = ActorScope::enter(&clock, "producer");
        let s = Arc::new(ShardQueue::with_clock(8, clock.clone()));
        let actor = clock.register_actor("consumer");
        let (s2, c2) = (s.clone(), clock.clone());
        // detlint: allow(thread-spawn) -- actor pre-registered above; the
        // thread attaches before touching simulated time
        let h = std::thread::spawn(move || {
            let _scope = ActorScope::attach(&c2, actor);
            let got = s2.pop_wait(4, Duration::from_secs(5));
            (got, c2.now())
        });
        clock.sleep(Duration::from_millis(30));
        s.try_push(req(9)).unwrap();
        clock.suspend_current();
        let (got, woke_at) = h.join().unwrap();
        clock.resume_current();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 9);
        assert_eq!(
            woke_at,
            crate::clock::ticks(Duration::from_millis(30)),
            "push, not the 5 s timeout, must wake the consumer"
        );
    }

    #[test]
    fn pop_wait_virtual_time_times_out_at_exact_deadline() {
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _me = ActorScope::enter(&clock, "consumer");
        let s = ShardQueue::with_clock(8, clock.clone());
        assert!(s.pop_wait(4, Duration::from_millis(20)).is_empty());
        assert_eq!(clock.now(), crate::clock::ticks(Duration::from_millis(20)));
    }

    #[test]
    fn pop_wait_returns_a_push_landing_exactly_at_the_deadline_tick() {
        // Virtual-time pin of the deadline-edge contract: the producer is
        // actor 0 and sleeps to exactly the consumer's deadline tick, so
        // the scheduler runs the push *before* the waiter's deadline turn.
        // The waiter must return the request — waking at exactly the
        // deadline tick — not an empty timeout.
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _me = ActorScope::enter(&clock, "producer");
        let s = Arc::new(ShardQueue::with_clock(8, clock.clone()));
        let actor = clock.register_actor("consumer");
        let (s2, c2) = (s.clone(), clock.clone());
        // detlint: allow(thread-spawn) -- actor pre-registered above; the
        // thread attaches before touching simulated time
        let h = std::thread::spawn(move || {
            let _scope = ActorScope::attach(&c2, actor);
            let got = s2.pop_wait(4, Duration::from_millis(20));
            (got, c2.now())
        });
        clock.sleep(Duration::from_millis(20));
        s.try_push(req(11)).unwrap();
        clock.suspend_current();
        let (got, woke_at) = h.join().unwrap();
        clock.resume_current();
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![11]);
        assert_eq!(
            woke_at,
            crate::clock::ticks(Duration::from_millis(20)),
            "the deadline-tick push must be served at the deadline tick"
        );
    }

    /// Clock wrapper reproducing the `pop_wait` deadline race the final
    /// drain fixes: its second `now()` call — `pop_wait`'s deadline check
    /// after an empty take — pushes a request, landing it exactly in the
    /// window between the empty re-check and the `now >= deadline` branch.
    #[derive(Debug)]
    struct RaceClock {
        inner: Arc<dyn Clock>,
        queue: Mutex<Option<Arc<ShardQueue>>>,
        now_calls: AtomicUsize,
    }

    impl Clock for RaceClock {
        fn now(&self) -> Tick {
            if self.now_calls.fetch_add(1, Ordering::SeqCst) == 1 {
                if let Some(q) = self.queue.lock().unwrap().clone() {
                    q.push_unbounded(req(42));
                }
            }
            self.inner.now()
        }
        fn sleep(&self, d: Duration) {
            self.inner.sleep(d);
        }
        fn new_slot(&self) -> Arc<WaitSlot> {
            self.inner.new_slot()
        }
        fn wait_slot(&self, slot: &WaitSlot, observed_gen: u64, timeout: Duration) {
            self.inner.wait_slot(slot, observed_gen, timeout);
        }
        fn notify_slot(&self, slot: &WaitSlot) {
            self.inner.notify_slot(slot);
        }
    }

    #[test]
    fn pop_wait_drains_a_push_racing_the_deadline_check() {
        // Regression for the stranded-push bug: before the final drain,
        // this exact interleaving returned empty and left id 42 queued
        // with its notify generation already consumed by the waiter.
        let race = Arc::new(RaceClock {
            inner: clock::wall(),
            queue: Mutex::new(None),
            now_calls: AtomicUsize::new(0),
        });
        let clock: Arc<dyn Clock> = race.clone();
        let s = Arc::new(ShardQueue::with_clock(8, clock));
        *race.queue.lock().unwrap() = Some(s.clone());
        // now() #1 computes the (zero-wait) deadline; the empty take runs;
        // now() #2 injects the push and then reports the deadline passed.
        let got = s.pop_wait(4, Duration::ZERO);
        assert_eq!(
            got.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![42],
            "the deadline-racing push must be drained, not stranded"
        );
        assert!(s.is_empty());
    }

    #[test]
    fn gating_flag_parks_and_ungating_wakes_virtual_time() {
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _me = ActorScope::enter(&clock, "cc");
        let s = Arc::new(ShardQueue::with_clock(8, clock.clone()));
        assert!(!s.is_gated());
        s.set_gated(true);
        assert!(s.is_gated());
        // A gated park with no wakeup returns at exactly its timeout.
        s.park_while_gated(Duration::from_millis(20));
        assert_eq!(clock.now(), crate::clock::ticks(Duration::from_millis(20)));
        // Ungating wakes a parked worker long before its timeout.
        let actor = clock.register_actor("worker");
        let (s2, c2) = (s.clone(), clock.clone());
        // detlint: allow(thread-spawn) -- actor pre-registered above; the
        // thread attaches before touching simulated time
        let h = std::thread::spawn(move || {
            let _scope = ActorScope::attach(&c2, actor);
            s2.park_while_gated(Duration::from_secs(60));
            c2.now()
        });
        clock.sleep(Duration::from_millis(30));
        s.set_gated(false);
        clock.suspend_current();
        let woke_at = h.join().unwrap();
        clock.resume_current();
        assert_eq!(woke_at, crate::clock::ticks(Duration::from_millis(50)));
        // An ungated park returns immediately, no time passes.
        let before = clock.now();
        s.park_while_gated(Duration::from_secs(60));
        assert_eq!(clock.now(), before);
    }

    #[test]
    fn failed_flag_is_independent_of_gating() {
        let s = ShardQueue::new(4);
        assert!(!s.is_failed());
        s.set_failed(true);
        assert!(s.is_failed());
        assert!(!s.is_gated(), "failure marking alone must not gate");
        // The CC gates a failed shard through the normal gating path; the
        // two flags stay independently settable (recovery can ungate
        // while a later scale-down re-gates the same shard).
        s.set_gated(true);
        s.set_failed(false);
        assert!(s.is_gated());
        assert!(!s.is_failed());
    }

    #[test]
    fn drain_all_empties_in_fifo_order() {
        let s = ShardQueue::new(8);
        for i in 0..5 {
            s.try_push(req(i)).unwrap();
        }
        let drained = s.drain_all();
        assert_eq!(drained.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
        assert!(s.is_empty());
        assert!(s.drain_all().is_empty());
    }

    /// `locked()` recovers a poisoned staging mutex instead of panicking
    /// or dropping admitted work (the queue holds plain requests, so a
    /// panicking peer cannot have left a broken invariant behind). Std
    /// mutexes only — the loom shim's mutex has no poisoning.
    #[test]
    #[cfg(not(loom))]
    fn poisoned_staging_lock_recovers_without_losing_requests() {
        let s = Arc::new(ShardQueue::new(4));
        s.try_push(req(1)).unwrap();
        s.try_push(req(2)).unwrap();

        // Poison the staging mutex: a worker panicking mid-reap.
        let sc = Arc::clone(&s);
        // detlint: allow(thread-spawn) -- poisoning test; no simulated time
        let panicked = std::thread::spawn(move || {
            let _guard = sc.staging.lock().unwrap();
            panic!("simulated worker panic while holding the staging lock");
        })
        .join();
        assert!(panicked.is_err());
        assert!(s.staging.is_poisoned(), "the panic must have poisoned the lock");

        // Every consumer path still sees both requests, in order.
        assert_eq!(s.pop_upto(1).iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!(s.drain_all().iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        assert!(s.is_empty());

        // And the producer/consumer cycle keeps working afterwards.
        s.try_push(req(3)).unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s.steal_upto(4).iter().map(|r| r.id).collect::<Vec<_>>(), vec![3]);
        assert!(s.is_empty());
    }
}
