//! Bounded per-instance shard queues (DESIGN.md S11.2).
//!
//! The serving path used to funnel every request through one global
//! `Mutex<VecDeque>`; under many instances the single lock and condvar
//! become the scaling bottleneck. A [`ShardQueue`] is owned by exactly one
//! worker (its *home* shard) and bounded individually, so submit-side
//! backpressure and wakeups touch one shard lock instead of a global one.
//! Idle workers may *steal* from sibling shards (`claim_batch` in
//! `coordinator::node`) which keeps tail latency flat when the
//! dispatcher's load estimate lags reality. Stealing — like the shards
//! themselves — is strictly node-local in a multi-node fleet (DESIGN.md
//! S21): cross-node movement of queued work happens only through a
//! migration's drain + re-dispatch.
//!
//! A relaxed atomic `depth` mirrors the queue length so dispatchers can
//! pick the least-loaded shard without taking any lock.
//!
//! For the elastic capacity manager (DESIGN.md S6.1) a shard can be
//! **gated**: dispatchers and stealing skip it, its worker parks on the
//! shard's wait slot ([`ShardQueue::park_while_gated`]) until scale-up or
//! shutdown wakes it, and the Central Controller drains whatever was
//! queued into the still-active shards each epoch.
//!
//! Every blocking wait goes through the shard's injected
//! [`Clock`](crate::clock::Clock) (DESIGN.md S18): under `WallClock` the
//! behavior is the classic timed condvar wait; under `VirtualClock` the
//! worker parks in simulation time, so a whole serving run is
//! deterministic. Lost wakeups are prevented by the slot's generation
//! counter — the waiter samples it *before* re-checking the queue, and a
//! notify that lands in between makes the wait return immediately.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use crate::clock::{self, Clock, WaitSlot};

use super::Request;

/// A bounded MPSC-style request queue owned by one worker instance.
#[derive(Debug)]
pub struct ShardQueue {
    q: Mutex<VecDeque<Request>>,
    clock: Arc<dyn Clock>,
    slot: Arc<WaitSlot>,
    depth: AtomicUsize,
    capacity: usize,
    gated: AtomicBool,
    failed: AtomicBool,
}

impl ShardQueue {
    /// Create a wall-clock shard bounded to `capacity` queued requests
    /// (min 1).
    pub fn new(capacity: usize) -> Self {
        Self::with_clock(capacity, clock::wall())
    }

    /// Create a shard whose blocking waits go through `clock` (the fleet
    /// passes its own clock so `VirtualClock` runs are deterministic).
    pub fn with_clock(capacity: usize, clock: Arc<dyn Clock>) -> Self {
        let slot = clock.new_slot();
        ShardQueue {
            q: Mutex::new(VecDeque::new()),
            clock,
            slot,
            depth: AtomicUsize::new(0),
            capacity: capacity.max(1),
            gated: AtomicBool::new(false),
            failed: AtomicBool::new(false),
        }
    }

    /// Take the queue lock, recovering from poisoning: a `VecDeque` of
    /// requests has no invariant a panicking peer could have broken, and
    /// losing queued requests to a poisoned lock would drop admitted work.
    fn locked(&self) -> MutexGuard<'_, VecDeque<Request>> {
        match self.q.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Maximum number of queued requests before pushes are refused.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Lock-free depth estimate (exact between lock releases).
    pub fn len(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// True when the shard currently holds no requests.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when the elastic capacity manager has gated this shard's
    /// instance (dispatch and stealing skip it; its worker is parked).
    pub fn is_gated(&self) -> bool {
        self.gated.load(Ordering::SeqCst)
    }

    /// Gate or ungate the shard. Ungating wakes the parked worker; the
    /// slot's generation counter makes the wakeup race-free — a worker
    /// that read the gated flag just before this call sees a moved
    /// generation and returns from its wait immediately.
    pub fn set_gated(&self, gated: bool) {
        self.gated.store(gated, Ordering::SeqCst);
        if !gated {
            self.clock.notify_slot(&self.slot);
        }
    }

    /// True when the fault-injection layer marked this shard's board as
    /// failed (DESIGN.md S20). Informational: the Central Controller
    /// *also* gates a failed shard, so dispatch, stealing and the worker
    /// park all flow through the existing gating machinery — this flag
    /// only distinguishes "down" from "scaled down" in stats and reports.
    pub fn is_failed(&self) -> bool {
        self.failed.load(Ordering::SeqCst)
    }

    /// Mark the shard's board failed/recovered (set by the CC at epoch
    /// boundaries from the active `FaultPlan`, cleared on shutdown).
    pub fn set_failed(&self, failed: bool) {
        self.failed.store(failed, Ordering::SeqCst);
    }

    /// Park the calling worker while the shard is gated; returns when
    /// ungated, woken (shutdown), or after `timeout` so the caller can
    /// re-check its stop flag.
    pub fn park_while_gated(&self, timeout: Duration) {
        // Sample the generation before the flag check (lost-wakeup guard).
        let observed = self.slot.generation();
        if !self.is_gated() {
            return;
        }
        self.clock.wait_slot(&self.slot, observed, timeout);
    }

    /// Enqueue a request; on a full shard the request is handed back so
    /// the dispatcher can retry elsewhere or reject (backpressure).
    pub fn try_push(&self, r: Request) -> Result<(), Request> {
        {
            let mut q = self.locked();
            if q.len() >= self.capacity {
                return Err(r);
            }
            q.push_back(r);
            self.depth.store(q.len(), Ordering::Relaxed);
        }
        self.clock.notify_slot(&self.slot);
        Ok(())
    }

    /// Enqueue ignoring the capacity bound. Only the Central Controller's
    /// drain/re-dispatch path uses this: a request that was *already
    /// admitted* must never be dropped, even if every shard it could move
    /// to filled up concurrently.
    pub fn push_unbounded(&self, r: Request) {
        {
            let mut q = self.locked();
            q.push_back(r);
            self.depth.store(q.len(), Ordering::Relaxed);
        }
        self.clock.notify_slot(&self.slot);
    }

    /// Dequeue up to `max` requests without blocking.
    pub fn pop_upto(&self, max: usize) -> Vec<Request> {
        let mut q = self.locked();
        let n = q.len().min(max);
        let out: Vec<Request> = q.drain(..n).collect();
        self.depth.store(q.len(), Ordering::Relaxed);
        out
    }

    /// Dequeue up to `max` requests, waiting up to `wait` for the first
    /// one to arrive. Returns empty only once `wait` has fully elapsed on
    /// the shard's clock with nothing queued.
    pub fn pop_wait(&self, max: usize, wait: Duration) -> Vec<Request> {
        let deadline = self.clock.now().saturating_add(clock::ticks(wait));
        loop {
            let observed = self.slot.generation();
            {
                let mut q = self.locked();
                if !q.is_empty() {
                    let n = q.len().min(max);
                    let out: Vec<Request> = q.drain(..n).collect();
                    self.depth.store(q.len(), Ordering::Relaxed);
                    return out;
                }
            }
            let now = self.clock.now();
            if now >= deadline {
                return Vec::new();
            }
            self.clock
                .wait_slot(&self.slot, observed, clock::to_duration(deadline - now));
        }
    }

    /// Take up to `max` requests from the *back* of the queue (work
    /// stealing; the home worker keeps FIFO order at the front).
    pub fn steal_upto(&self, max: usize) -> Vec<Request> {
        let mut q = self.locked();
        let n = q.len().min(max);
        let keep = q.len() - n;
        let out: Vec<Request> = q.split_off(keep).into_iter().collect();
        self.depth.store(q.len(), Ordering::Relaxed);
        out
    }

    /// Drain the whole queue in FIFO order (the CC's gated-shard drain).
    pub fn drain_all(&self) -> Vec<Request> {
        let mut q = self.locked();
        let out: Vec<Request> = q.drain(..).collect();
        self.depth.store(0, Ordering::Relaxed);
        out
    }

    /// Wake every waiter (used on shutdown).
    pub fn wake_all(&self) {
        self.clock.notify_slot(&self.slot);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{ActorScope, VirtualClock};

    fn req(id: u64) -> Request {
        Request { id, payload: vec![0.0; 4], submitted: 0 }
    }

    #[test]
    fn bounded_push_applies_backpressure() {
        let s = ShardQueue::new(2);
        assert!(s.try_push(req(0)).is_ok());
        assert!(s.try_push(req(1)).is_ok());
        let back = s.try_push(req(2));
        assert!(back.is_err(), "third push must be refused");
        assert_eq!(back.unwrap_err().id, 2, "refused request is handed back");
        assert_eq!(s.len(), 2);
        assert_eq!(s.capacity(), 2);
        // The drain path may exceed the bound so admitted work survives.
        s.push_unbounded(req(3));
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn pop_preserves_fifo_and_depth() {
        let s = ShardQueue::new(16);
        for i in 0..5 {
            s.try_push(req(i)).unwrap();
        }
        assert_eq!(s.len(), 5);
        let a = s.pop_upto(3);
        assert_eq!(a.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(s.len(), 2);
        let b = s.pop_upto(10);
        assert_eq!(b.len(), 2);
        assert!(s.is_empty());
        assert!(s.pop_upto(4).is_empty());
    }

    #[test]
    fn steal_takes_from_the_back() {
        let s = ShardQueue::new(16);
        for i in 0..6 {
            s.try_push(req(i)).unwrap();
        }
        let stolen = s.steal_upto(2);
        assert_eq!(stolen.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4, 5]);
        // Home worker still sees FIFO order at the front.
        let own = s.pop_upto(10);
        assert_eq!(own.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn pop_wait_returns_queued_work_without_waiting() {
        let s = ShardQueue::new(8);
        s.try_push(req(7)).unwrap();
        // Zero timeout: queued work is still returned immediately.
        let got = s.pop_wait(4, Duration::ZERO);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 7);
        assert!(s.pop_wait(4, Duration::from_millis(5)).is_empty());
    }

    #[test]
    fn pop_wait_virtual_time_wakes_on_push_deterministically() {
        // No real sleeps: the consumer parks in virtual time, the producer
        // pushes 30 virtual ms later, and the wakeup tick is exact.
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _me = ActorScope::enter(&clock, "producer");
        let s = Arc::new(ShardQueue::with_clock(8, clock.clone()));
        let actor = clock.register_actor("consumer");
        let (s2, c2) = (s.clone(), clock.clone());
        let h = std::thread::spawn(move || {
            let _scope = ActorScope::attach(&c2, actor);
            let got = s2.pop_wait(4, Duration::from_secs(5));
            (got, c2.now())
        });
        clock.sleep(Duration::from_millis(30));
        s.try_push(req(9)).unwrap();
        clock.suspend_current();
        let (got, woke_at) = h.join().unwrap();
        clock.resume_current();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 9);
        assert_eq!(
            woke_at,
            crate::clock::ticks(Duration::from_millis(30)),
            "push, not the 5 s timeout, must wake the consumer"
        );
    }

    #[test]
    fn pop_wait_virtual_time_times_out_at_exact_deadline() {
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _me = ActorScope::enter(&clock, "consumer");
        let s = ShardQueue::with_clock(8, clock.clone());
        assert!(s.pop_wait(4, Duration::from_millis(20)).is_empty());
        assert_eq!(clock.now(), crate::clock::ticks(Duration::from_millis(20)));
    }

    #[test]
    fn gating_flag_parks_and_ungating_wakes_virtual_time() {
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _me = ActorScope::enter(&clock, "cc");
        let s = Arc::new(ShardQueue::with_clock(8, clock.clone()));
        assert!(!s.is_gated());
        s.set_gated(true);
        assert!(s.is_gated());
        // A gated park with no wakeup returns at exactly its timeout.
        s.park_while_gated(Duration::from_millis(20));
        assert_eq!(clock.now(), crate::clock::ticks(Duration::from_millis(20)));
        // Ungating wakes a parked worker long before its timeout.
        let actor = clock.register_actor("worker");
        let (s2, c2) = (s.clone(), clock.clone());
        let h = std::thread::spawn(move || {
            let _scope = ActorScope::attach(&c2, actor);
            s2.park_while_gated(Duration::from_secs(60));
            c2.now()
        });
        clock.sleep(Duration::from_millis(30));
        s.set_gated(false);
        clock.suspend_current();
        let woke_at = h.join().unwrap();
        clock.resume_current();
        assert_eq!(woke_at, crate::clock::ticks(Duration::from_millis(50)));
        // An ungated park returns immediately, no time passes.
        let before = clock.now();
        s.park_while_gated(Duration::from_secs(60));
        assert_eq!(clock.now(), before);
    }

    #[test]
    fn failed_flag_is_independent_of_gating() {
        let s = ShardQueue::new(4);
        assert!(!s.is_failed());
        s.set_failed(true);
        assert!(s.is_failed());
        assert!(!s.is_gated(), "failure marking alone must not gate");
        // The CC gates a failed shard through the normal gating path; the
        // two flags stay independently settable (recovery can ungate
        // while a later scale-down re-gates the same shard).
        s.set_gated(true);
        s.set_failed(false);
        assert!(s.is_gated());
        assert!(!s.is_failed());
    }

    #[test]
    fn drain_all_empties_in_fifo_order() {
        let s = ShardQueue::new(8);
        for i in 0..5 {
            s.try_push(req(i)).unwrap();
        }
        let drained = s.drain_all();
        assert_eq!(drained.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
        assert!(s.is_empty());
        assert!(s.drain_all().is_empty());
    }
}
