//! Bounded per-instance shard queues (DESIGN.md S11.2).
//!
//! The serving path used to funnel every request through one global
//! `Mutex<VecDeque>`; under many instances the single lock and condvar
//! become the scaling bottleneck. A [`ShardQueue`] is owned by exactly one
//! worker (its *home* shard) and bounded individually, so submit-side
//! backpressure and wakeups touch one shard lock instead of a global one.
//! Idle workers may *steal* from sibling shards (see
//! [`claim_batch`](super::fleet)) which keeps tail latency flat when the
//! dispatcher's load estimate lags reality.
//!
//! A relaxed atomic `depth` mirrors the queue length so dispatchers can
//! pick the least-loaded shard without taking any lock.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use super::Request;

/// A bounded MPSC-style request queue owned by one worker instance.
#[derive(Debug)]
pub struct ShardQueue {
    q: Mutex<VecDeque<Request>>,
    notify: Condvar,
    depth: AtomicUsize,
    capacity: usize,
}

impl ShardQueue {
    /// Create a shard bounded to `capacity` queued requests (min 1).
    pub fn new(capacity: usize) -> Self {
        ShardQueue {
            q: Mutex::new(VecDeque::new()),
            notify: Condvar::new(),
            depth: AtomicUsize::new(0),
            capacity: capacity.max(1),
        }
    }

    /// Maximum number of queued requests before pushes are refused.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Lock-free depth estimate (exact between lock releases).
    pub fn len(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// True when the shard currently holds no requests.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue a request; on a full shard the request is handed back so
    /// the dispatcher can retry elsewhere or reject (backpressure).
    pub fn try_push(&self, r: Request) -> Result<(), Request> {
        let mut q = self.q.lock().unwrap();
        if q.len() >= self.capacity {
            return Err(r);
        }
        q.push_back(r);
        self.depth.store(q.len(), Ordering::Relaxed);
        drop(q);
        self.notify.notify_one();
        Ok(())
    }

    /// Dequeue up to `max` requests without blocking.
    pub fn pop_upto(&self, max: usize) -> Vec<Request> {
        let mut q = self.q.lock().unwrap();
        let n = q.len().min(max);
        let out: Vec<Request> = q.drain(..n).collect();
        self.depth.store(q.len(), Ordering::Relaxed);
        out
    }

    /// Dequeue up to `max` requests, waiting up to `wait` for the first
    /// one to arrive. Returns early (possibly empty) when woken.
    pub fn pop_wait(&self, max: usize, wait: Duration) -> Vec<Request> {
        let mut q = self.q.lock().unwrap();
        if q.is_empty() {
            let (qq, _timeout) = self.notify.wait_timeout(q, wait).unwrap();
            q = qq;
        }
        let n = q.len().min(max);
        let out: Vec<Request> = q.drain(..n).collect();
        self.depth.store(q.len(), Ordering::Relaxed);
        out
    }

    /// Take up to `max` requests from the *back* of the queue (work
    /// stealing; the home worker keeps FIFO order at the front).
    pub fn steal_upto(&self, max: usize) -> Vec<Request> {
        let mut q = self.q.lock().unwrap();
        let n = q.len().min(max);
        let keep = q.len() - n;
        let out: Vec<Request> = q.split_off(keep).into_iter().collect();
        self.depth.store(q.len(), Ordering::Relaxed);
        out
    }

    /// Wake every waiter (used on shutdown).
    pub fn wake_all(&self) {
        self.notify.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn req(id: u64) -> Request {
        Request { id, payload: vec![0.0; 4], submitted: Instant::now() }
    }

    #[test]
    fn bounded_push_applies_backpressure() {
        let s = ShardQueue::new(2);
        assert!(s.try_push(req(0)).is_ok());
        assert!(s.try_push(req(1)).is_ok());
        let back = s.try_push(req(2));
        assert!(back.is_err(), "third push must be refused");
        assert_eq!(back.unwrap_err().id, 2, "refused request is handed back");
        assert_eq!(s.len(), 2);
        assert_eq!(s.capacity(), 2);
    }

    #[test]
    fn pop_preserves_fifo_and_depth() {
        let s = ShardQueue::new(16);
        for i in 0..5 {
            s.try_push(req(i)).unwrap();
        }
        assert_eq!(s.len(), 5);
        let a = s.pop_upto(3);
        assert_eq!(a.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(s.len(), 2);
        let b = s.pop_upto(10);
        assert_eq!(b.len(), 2);
        assert!(s.is_empty());
        assert!(s.pop_upto(4).is_empty());
    }

    #[test]
    fn steal_takes_from_the_back() {
        let s = ShardQueue::new(16);
        for i in 0..6 {
            s.try_push(req(i)).unwrap();
        }
        let stolen = s.steal_upto(2);
        assert_eq!(stolen.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4, 5]);
        // Home worker still sees FIFO order at the front.
        let own = s.pop_upto(10);
        assert_eq!(own.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn pop_wait_times_out_empty_and_wakes_on_push() {
        let s = std::sync::Arc::new(ShardQueue::new(8));
        let t0 = Instant::now();
        assert!(s.pop_wait(4, Duration::from_millis(20)).is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(15));

        let s2 = s.clone();
        let h = std::thread::spawn(move || s2.pop_wait(4, Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(30));
        s.try_push(req(9)).unwrap();
        let got = h.join().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 9);
    }
}
