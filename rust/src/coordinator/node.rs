//! Node agent — one serving box of the fleet-of-fleets (DESIGN.md S21).
//!
//! A node owns the *data plane* for every group it may ever host: one
//! [`GroupSlice`] per group (bounded shard queues + dispatcher + arrival
//! counter) and one worker thread per (group, instance). Which slices are
//! live is decided by the fleet's
//! [`TopologyStore`](super::topology::TopologyStore): non-hosted slices
//! start gated (their workers park on the shard condvar), and the node's
//! CC thread adopts a group — controller, backlog, trace and all — when
//! the topology says so.
//!
//! The per-epoch decision loop is the *identical*
//! [`GroupController`](crate::control::GroupController) engine the
//! single-process CC and the offline platform run (DESIGN.md S19): the
//! whole epoch pass moved here verbatim from the pre-split `fleet.rs`
//! monolith, so a 1-node fleet is bit-identical to the legacy path and an
//! N-node migration-free fleet produces the same per-group decision logs
//! (`tests/control_equivalence.rs`).
//!
//! Migration is controller hand-off plus the PR 6 fault-drain machinery:
//! the source node flips the hosting bit in the store, gates its slice,
//! drains the backlog into the destination slice (re-dispatch, never a
//! drop), folds the source's uncounted arrivals into the controller's
//! residual, and deposits the [`GroupCc`] into the group's [`Handover`]
//! slot. The destination's CC adopts it at its next topology refresh.
//! Because every CC wakes at the same virtual instant and the
//! [`VirtualClock`](crate::clock::VirtualClock) runs same-deadline actors
//! in id order, a scripted move replays deterministically — the
//! conservation invariant `admitted == completed + failed` holds through
//! every move (`tests/sim_properties.rs::prop_migration_conserves_work`).

use std::time::Duration;

use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::{Arc, Mutex};

use crate::clock::{self, ActorScope};
use crate::control::{
    batch_amortization, ControlConfig, GroupController, LutSpec, Observation, QosTier,
};
use crate::markov::PredictorKind;
use crate::metrics::{Gauge, Registry};
use crate::power::DesignPower;
use crate::runtime::{Engine, OpQuery, VoltageSelectorClient};
use crate::vscale::{CapacityPolicy, Optimizer};

use super::backend::InferenceBackend;
use super::dispatch::Dispatcher;
use super::fleet::{volts_to_mv, FleetServingConfig, GroupShared, F_NOM_HZ};
use super::router;
use super::shard::ShardQueue;
use super::topology::{NodeHealth, TopologyStore};
use super::{EpochRecord, Request, SubmitError};

/// One node's share of one group's data plane: the shards its local
/// workers serve, the dispatcher that places submits across them, and the
/// arrival counter its CC reads. Exactly one node's slice per group is
/// live at a time (the hosting node); the others sit gated.
pub(super) struct GroupSlice {
    /// Bounded per-instance queues, worker `wid` ↔ `shards[wid]`.
    pub(super) shards: Vec<Arc<ShardQueue>>,
    /// Shard selection on the submit path (work stealing stays node-local).
    pub(super) dispatcher: Dispatcher,
    /// Offered demand this epoch — incremented at submit *before*
    /// placement so rejected requests still push the predictor up.
    pub(super) arrivals_this_epoch: AtomicU64,
}

impl GroupSlice {
    /// Requests currently queued across the slice's shards.
    pub(super) fn depth(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }
}

/// Shared state of one node: identity + one [`GroupSlice`] per group
/// (index-aligned with the fleet's groups).
pub(super) struct NodeShared {
    /// Node id (bit position in the topology's hosting masks).
    pub(super) id: usize,
    /// Display name (`node0`, ...), the metrics namespace prefix.
    pub(super) name: String,
    /// Per-group data planes, global group order.
    pub(super) slices: Vec<GroupSlice>,
}

/// Pull a batch for worker `wid`: first from its home shard (waiting up to
/// `wait` for the first request), then — when idle and `steal` is on —
/// from the deepest sibling shard. Gated siblings are skipped (their
/// backlog belongs to the CC's drain/re-dispatch pass). Returns the batch
/// and whether it was stolen.
pub(super) fn claim_batch(
    shards: &[Arc<ShardQueue>],
    wid: usize,
    max: usize,
    wait: Duration,
    steal: bool,
) -> (Vec<Request>, bool) {
    let batch = shards[wid].pop_wait(max, wait);
    if !batch.is_empty() || !steal || shards.len() < 2 {
        return (batch, false);
    }
    // Steal roughly half of the deepest sibling's backlog.
    let mut victim = None;
    let mut depth = 0usize;
    for (i, s) in shards.iter().enumerate() {
        if i != wid && !s.is_gated() && s.len() > depth {
            depth = s.len();
            victim = Some(i);
        }
    }
    match victim {
        Some(v) => {
            let take = depth.div_ceil(2).clamp(1, max);
            let stolen = shards[v].steal_upto(take);
            let got = !stolen.is_empty();
            (stolen, got)
        }
        None => (Vec::new(), false),
    }
}

/// Everything a worker spawn needs from the fleet, bundled so the call
/// sites stay readable.
pub(super) struct WorkerEnv<'a> {
    /// Fleet configuration (clock, fault plan, batch knobs).
    pub(super) cfg: &'a FleetServingConfig,
    /// Directory the inference backends open artifacts from.
    pub(super) artifacts_dir: &'a std::path::Path,
    /// Shared fleet registry (for the `fleet.completed` counter).
    pub(super) registry: &'a Registry,
    /// Shutdown flag.
    pub(super) stop: &'a Arc<AtomicBool>,
    /// 1-node fleet: keep the legacy actor labels (`{group}:w{wid}`).
    pub(super) single_node: bool,
}

/// Spawn one worker thread for `(node, group gi, instance wid)`,
/// registering its clock actor on the *calling* thread so actor ids are
/// assigned in deterministic program order. The loop body is the legacy
/// single-process worker, reading its shards from the node's slice.
pub(super) fn spawn_worker(
    env: &WorkerEnv<'_>,
    node: &Arc<NodeShared>,
    g: &Arc<GroupShared>,
    gi: usize,
    wid: usize,
) -> std::thread::JoinHandle<()> {
    let node = node.clone();
    let g = g.clone();
    let dir = env.artifacts_dir.to_path_buf();
    let stop = env.stop.clone();
    let fleet_completed = env.registry.counter("fleet.completed");
    let cycles = env.cfg.cycles_per_batch;
    let overhead = env.cfg.batch_overhead;
    let batch_timeout = env.cfg.batch_timeout;
    let steal = env.cfg.steal;
    let faults = env.cfg.faults.clone();
    let epoch_len = env.cfg.epoch;
    let clock = env.cfg.clock.clone();
    let label = if env.single_node {
        format!("{}:w{wid}", g.name)
    } else {
        format!("{}:{}:w{wid}", node.name, g.name)
    };
    // Advance-domain gi+1: a group's workers (across all nodes) share
    // group-global state, so they form one domain; domain 0 stays the
    // control domain (driver + CCs). Under the sequential engine the
    // domain tag is ignored (DESIGN.md S24).
    let actor = clock.register_actor_in(&label, gi + 1);
    // detlint: allow(thread-spawn) -- actor pre-registered above; the
    // thread attaches before touching simulated time
    std::thread::spawn(move || {
        let _actor = ActorScope::attach(&clock, actor);
        let shards = &node.slices[gi].shards;
        let backend = InferenceBackend::open(&dir, &g.name);
        // The artifact's fixed tensor geometry — the chunk size every
        // dispatch is padded to. The *claim target* is the CC's decided
        // batch (DESIGN.md S22), read fresh each iteration below.
        let geometry = backend.batch();
        let in_dim = backend.in_dim();
        let out_dim = backend.out_dim();
        loop {
            // Gated instance (scaled down, failed, or a non-hosting
            // node's replica): park on the shard condvar until the CC
            // scales back up, a migration lands here, or shutdown
            // starts. The timeout bounds a racily-missed wakeup.
            if shards[wid].is_gated() && !stop.load(Ordering::Relaxed) {
                shards[wid].park_while_gated(Duration::from_millis(25));
                continue;
            }
            // Honor the CC's decided batch: claim up to it (never below
            // the artifact geometry — a smaller claim would just pad).
            let claim_cap =
                (g.batch_now.load(Ordering::Relaxed) as usize).max(geometry).max(1);
            let (mut reqs, stolen) = claim_batch(shards, wid, claim_cap, batch_timeout, steal);
            if stolen {
                g.stolen_batches.inc();
            }
            if reqs.is_empty() {
                // Exit only once every admitted request has been served
                // or failed. After `stop` no new requests are admitted
                // (shutdown consumes the fleet), so `admitted` is frozen
                // and this equality is race-free — unlike a
                // queue-emptiness check, it also covers requests the
                // CC's gated-shard drain (or a migration) is holding
                // outside any queue. The counters are group-global, so
                // no worker exits while a sibling node still queues this
                // group's work. The Acquire on the stop flag pairs with
                // shutdown()'s Release store so every admitted.inc()
                // sequenced before shutdown is visible here; stale (low)
                // completed/failed reads only delay exit by a loop
                // iteration.
                if stop.load(Ordering::Acquire)
                    && g.admitted.get() == g.completed.get() + g.failed.get()
                {
                    return;
                }
                continue;
            }
            // Top up a partial batch without waiting.
            if reqs.len() < claim_cap {
                reqs.extend(shards[wid].pop_upto(claim_cap - reqs.len()));
            }

            // ---- real inference (PJRT or native) -----------
            // The decided batch can exceed the artifact's fixed tensor
            // geometry, so the claimed set is dispatched in
            // geometry-sized chunks, each padded to the full shape the
            // backend demands. A failing backend must not kill the
            // worker — a dead worker leaves its shard undrained and
            // shutdown() would wait on it forever — so failed chunks are
            // counted and skipped while the rest of the set proceeds.
            let n_chunks = reqs.len().div_ceil(geometry);
            let mut chunk_ok = vec![false; n_chunks];
            let mut y0 = vec![0.0f32; reqs.len()];
            let mut served = 0usize;
            for (ci, chunk) in reqs.chunks(geometry).enumerate() {
                let mut x = vec![0.0f32; geometry * in_dim];
                for (i, r) in chunk.iter().enumerate() {
                    x[i * in_dim..(i + 1) * in_dim].copy_from_slice(&r.payload);
                }
                match backend.infer(&x) {
                    Ok(y) => {
                        chunk_ok[ci] = true;
                        served += chunk.len();
                        for i in 0..chunk.len() {
                            y0[ci * geometry + i] = y[i * out_dim];
                        }
                    }
                    Err(_) => g.failed.add(chunk.len() as u64),
                }
            }
            if served == 0 {
                continue;
            }

            // ---- simulated FPGA occupancy ------------------
            // Service scales with batch *fill* (a 1-request dispatch no
            // longer pays the full cycles_per_batch the offline model
            // never charged it), plus a per-dispatch overhead fraction;
            // the (1 + overhead) normalizer keeps a full nominal batch
            // at exactly the classic cycles / f charge, so the realized
            // per-instance rate is (F_NOM/cycles)·geometry·fr times the
            // same batch_amortization factor the CC's capacity model
            // applies (DESIGN.md S22). A straggler window stretches the
            // service time by the plan's slowdown; outside a window the
            // factor is exactly 1.0. Fault-plan indices are
            // (group, shard), so the window follows the shard wherever
            // the group is hosted.
            let fr = g.freq_ratio().max(0.05);
            let slow =
                faults.straggler_slowdown(gi, wid, clock::epoch_index(clock.now(), epoch_len));
            let fill = served as f64 / geometry as f64;
            let service =
                cycles * (fill + overhead) / ((1.0 + overhead) * F_NOM_HZ * fr) * slow;
            clock.sleep(Duration::from_secs_f64(service));

            let now = clock.now();
            for (i, r) in reqs.iter().enumerate() {
                if !chunk_ok[i / geometry] {
                    continue;
                }
                let lat_ticks = now.saturating_sub(r.submitted);
                g.latency_us.observe(lat_ticks as f64 / 1e3);
                g.completed.inc();
                fleet_completed.inc();
                let _ = super::Completion {
                    id: r.id,
                    worker: wid,
                    latency: clock::to_duration(lat_ticks),
                    y0: y0[i],
                };
            }
        }
    })
}

/// The gauges one (node, group) pair publishes: the namespaced
/// `{node}.{group}.margin_now` / `.predictor_now` pair, plus the legacy
/// un-namespaced `{group}.*` alias on 1-node fleets (back-compat; on a
/// multi-node fleet two hosts of one group would collide on it).
pub(super) struct GroupGauges {
    margin: Vec<Arc<Gauge>>,
    predictor: Vec<Arc<Gauge>>,
}

impl GroupGauges {
    fn resolve(registry: &Registry, node_name: &str, group_name: &str, alias: bool) -> GroupGauges {
        let scope = format!("{node_name}.{group_name}");
        let mut margin = vec![registry.scoped_gauge(&scope, "margin_now")];
        let mut predictor = vec![registry.scoped_gauge(&scope, "predictor_now")];
        if alias {
            margin.push(registry.scoped_gauge(group_name, "margin_now"));
            predictor.push(registry.scoped_gauge(group_name, "predictor_now"));
        }
        GroupGauges { margin, predictor }
    }

    fn set(&self, margin: f64, predictor_idx: f64) {
        for gauge in &self.margin {
            gauge.set(margin);
        }
        for gauge in &self.predictor {
            gauge.set(predictor_idx);
        }
    }
}

/// One group's control-plane state, owned by exactly one node CC at a
/// time and handed over whole on migration: the shared controller, the
/// modeled backlog, the operating point that served the last epoch, and
/// the group's accumulated trace. Everything the decision loop needs —
/// so the destination resumes the sequence exactly where the source
/// stopped.
pub(super) struct GroupCc {
    /// Global group index.
    pub(super) gi: usize,
    design: DesignPower,
    optimizer: Optimizer,
    /// The shared per-group control plane (DESIGN.md S19): predictor,
    /// guardband, margin ladder and per-level elastic LUTs — the same
    /// engine the offline platform runs.
    pub(super) controller: GroupController,
    backlog: f64,
    cap: f64,
    // Operating point that served the epoch now ending (published at
    // the END of the previous pass).
    served_fr: f64,
    served_vcore: f64,
    served_vbram: f64,
    served_active: usize,
    /// Shards that actually served (the decision's active count minus
    /// fault-plan failures). Equals `served_active` whenever no board is
    /// failed, so fault-free capacity and energy are bit-identical to
    /// the pre-fault plant.
    served_healthy: usize,
    /// Boards failed while the epoch was served.
    served_failed: usize,
    /// Straggler capacity factor of the serving set (exactly 1.0
    /// without straggler windows).
    served_slow: f64,
    /// Batch size that served the epoch now ending (decided at the end
    /// of the previous pass; the nominal until the first adaptive
    /// decision lands). Mirrors the offline plant's `batch` field.
    served_batch: usize,
    /// Last published margin / predictor index — re-seeds the adopting
    /// node's gauges so a hand-off never rewinds the published surface.
    last_margin: f64,
    last_predictor_idx: usize,
    /// The group's epoch trace; travels with the controller so per-group
    /// records stay continuous across migrations.
    pub(super) records: Vec<EpochRecord>,
    /// Arrivals counted on a relinquishing node's slice after its last
    /// pass — folded into the adopting node's first pass so offered
    /// demand is never lost across a hand-off. Zero on the legacy path.
    residual_arrivals: u64,
    /// Consecutive epochs at-or-over the rebalancer's backlog threshold.
    sat_streak: usize,
}

impl GroupCc {
    /// Build the control plane for group `gi` — the legacy CC's
    /// per-group construction, verbatim. Pure compute (LUT builds), no
    /// clock access, so it runs on the fleet's starting thread.
    pub(super) fn new(
        gi: usize,
        design: DesignPower,
        optimizer: Optimizer,
        cfg: &FleetServingConfig,
        g: &GroupShared,
    ) -> GroupCc {
        // All decision machinery — margin ladder, LUT builds, guardband
        // — is the controller's (DESIGN.md S19); the CC only picks the
        // elastic LUT family matching its capacity policy.
        let controller = GroupController::new(
            ControlConfig {
                m_bins: cfg.m_bins,
                margin_t: cfg.margin_t,
                warmup: cfg.warmup_epochs,
                predictor: cfg.predictor,
                predictor_period: cfg.predictor_period,
                // Tenant tiers refine only an *enabled* run-level
                // guardband (DESIGN.md S20); qos_target None keeps every
                // baseline bit-identical regardless of tier.
                qos_target: QosTier::effective(cfg.qos_target, cfg.groups[gi].qos_target),
                batch_nominal: cfg.batch_nominal,
                adaptive_batch: cfg.adaptive_batch,
            },
            &optimizer,
            LutSpec::Elastic {
                mode: cfg.mode,
                n_instances: g.n_instances,
                residual: cfg.pg_residual,
                policy: cfg.capacity_policy,
                latency_cap_sw: f64::INFINITY,
            },
        );
        let cap = g.n_instances as f64
            * (F_NOM_HZ / cfg.cycles_per_batch)
            * g.batch as f64
            * cfg.epoch.as_secs_f64();
        let served_vcore = design.chars.logic.v_nom;
        let served_vbram = design.chars.bram.v_nom;
        let last_predictor_idx = PredictorKind::index_of_name(controller.predictor_now());
        GroupCc {
            gi,
            design,
            optimizer,
            controller,
            backlog: 0.0,
            cap,
            served_fr: 1.0,
            served_vcore,
            served_vbram,
            served_active: g.n_instances,
            served_healthy: g.n_instances,
            served_failed: 0,
            // Epoch 0 is served before any CC pass, so no board is gated
            // yet; straggler windows may still cover it.
            served_slow: {
                let all: Vec<usize> = (0..g.n_instances).collect();
                cfg.faults.capacity_factor(gi, &all, 0)
            },
            served_batch: cfg.batch_nominal.max(1),
            last_margin: cfg.margin_t,
            last_predictor_idx,
            records: Vec::new(),
            residual_arrivals: 0,
            sat_streak: 0,
        }
    }

    /// One CC epoch pass for this group — the legacy monolith's per-group
    /// loop body, moved verbatim (same float expression shapes, so the
    /// 1-node path is bit-identical to the pre-split coordinator).
    pub(super) fn run_epoch(
        &mut self,
        g: &GroupShared,
        slice: &GroupSlice,
        cfg: &FleetServingConfig,
        engine: Option<&Engine>,
        gauges: &GroupGauges,
        epoch: usize,
    ) {
        let gi = self.gi;
        // Residual arrivals are 0 except on the first pass after a
        // hand-off, so the u64 sum is exact and the legacy path is
        // bit-identical.
        let arrivals = (slice.arrivals_this_epoch.swap(0, Ordering::Relaxed)
            + std::mem::take(&mut self.residual_arrivals)) as f64;
        let load = (arrivals / self.cap).min(1.0);

        // ---- per-tenant QoS accounting ------------------
        // Demand is judged against the capacity that actually served
        // this epoch — active instances × their frequency — not the one
        // about to be published. (Same expression shape as the offline
        // plant's capacity so the two paths' float results are
        // bit-identical.) Failures shrink the serving set
        // (`served_healthy <= served_active`) and straggler windows
        // scale it by the mean service-rate factor; both are exactly
        // neutral on an empty fault plan.
        // Batch amortization multiplies LAST (DESIGN.md S22): it is an
        // exact 1.0 at the nominal batch and the offline plant appends
        // the same factor to the same product shape, so fixed-batch runs
        // and the cross-path equivalence contract stay bit-identical.
        let served_cap = self.served_fr
            * (self.served_healthy as f64 / g.n_instances as f64)
            * self.served_slow
            * batch_amortization(self.served_batch, cfg.batch_nominal, cfg.batch_overhead);
        let demand = load + self.backlog;
        let delivered = demand.min(served_cap);
        self.backlog = (demand - delivered).min(cfg.max_backlog_steps);
        let violated = demand - delivered > 1e-9;
        if violated {
            g.violations.inc();
        }

        // ---- one decision via the shared control plane --
        // Misprediction judgement, predictor training, guardband
        // feedback, margin-ladder quantization, backlog backpressure and
        // the elastic LUT lookup all live in control::GroupController
        // (DESIGN.md S19) — the exact engine the offline platform runs
        // per step.
        let d = self.controller.decide(&Observation {
            load,
            qos_violation: violated,
            backlog: self.backlog,
        });

        // Refine through the AOT'd Voltage Selector when available; keep
        // the native point on any error. PG-only pins active instances
        // at nominal V/f, so its point is never refined. (Serving-side
        // refinement, not a control decision: virtual-time runs skip it
        // so the decision log stays environment-independent.)
        let (mut vcore_next, mut vbram_next) = (d.vcore, d.vbram);
        if cfg.capacity_policy != CapacityPolicy::GatingOnly {
            if let Some(engine) = engine {
                let vs = VoltageSelectorClient::new(engine);
                let q = OpQuery {
                    alpha: self.optimizer.tables.op.alpha as f32,
                    beta: self.optimizer.tables.op.beta as f32,
                    gamma_l: self.optimizer.tables.op.gamma_l as f32,
                    gamma_m: self.optimizer.tables.op.gamma_m as f32,
                    sw: (1.0 / d.freq_ratio) as f32,
                };
                if let Ok(choices) = vs.select(cfg.mode, &self.optimizer.tables, &[q]) {
                    if let Some(c) = choices.first() {
                        vcore_next = c.vcore;
                        vbram_next = c.vbram;
                    }
                }
            }
        }

        // ---- energy integration + trace row -------------
        // Charged at the point that served the epoch; the freshly chosen
        // point is charged next epoch. Active instances at the scaled
        // point, gated ones at the residual of nominal.
        let f_mhz = self.design.spec.freq_mhz * self.served_fr;
        let p_board = self
            .design
            .breakdown(self.served_vcore, self.served_vbram, f_mhz)
            .total_w();
        let board_nom = self.design.nominal().total_w();
        // Failed boards are powered down like gated ones (residual
        // draw), so energy charges the healthy serving set only.
        let gated = (g.n_instances - self.served_healthy) as f64;
        let p = p_board * self.served_healthy as f64 + board_nom * cfg.pg_residual * gated;
        let p_nom = board_nom * g.n_instances as f64;
        g.energy_j.add(p * cfg.epoch.as_secs_f64());
        g.nominal_energy_j.add(p_nom * cfg.epoch.as_secs_f64());
        g.epochs.inc();
        // Same column alignment as the offline StepRecord: the operating
        // point that SERVED this epoch, plus the
        // forecast/margin/predictor of the decision MADE this epoch.
        self.records.push(EpochRecord {
            epoch,
            load,
            decision: crate::control::DecisionRecord {
                predicted: d.predicted,
                freq_ratio: self.served_fr,
                vcore: self.served_vcore,
                vbram: self.served_vbram,
                n_active: self.served_active,
                batch: self.served_batch,
                predictor: d.predictor,
                margin: d.margin,
            },
            power_w: p,
            n_failed: self.served_failed,
            slow_factor: self.served_slow,
        });

        // ---- publish the next operating point -----------
        g.freq_ratio.store(d.freq_ratio.to_bits(), Ordering::Relaxed);
        g.vcore_mv.store(volts_to_mv(vcore_next), Ordering::Relaxed);
        g.vbram_mv.store(volts_to_mv(vbram_next), Ordering::Relaxed);
        g.active_now.store(d.n_active as u64, Ordering::Relaxed);
        // Workers read this as their claim target: the decided batch for
        // the next epoch (the nominal whenever adaptive_batch is off).
        g.batch_now.store(d.batch as u64, Ordering::Relaxed);
        g.margin_now.store(d.margin.to_bits(), Ordering::Relaxed);
        g.predictor_now
            .store(PredictorKind::index_of_name(d.predictor) as u64, Ordering::Relaxed);
        self.last_margin = d.margin;
        self.last_predictor_idx = PredictorKind::index_of_name(d.predictor);
        gauges.set(self.last_margin, self.last_predictor_idx as f64);

        // ---- gate / ungate + drain ----------------------
        // The serving set for the next epoch is the first `n_active`
        // *non-failed* shards (DESIGN.md S20). Without failures that is
        // exactly [0, n_active), the pre-fault behavior. Everything
        // outside the set — gated by the decision OR downed by the plan
        // — is drained and re-dispatched into it so admitted requests
        // are never dropped.
        let next_epoch = epoch + 1;
        let failed_mask: Vec<bool> = (0..g.n_instances)
            .map(|i| cfg.faults.board_failed(gi, i, next_epoch))
            .collect();
        let n_failed = failed_mask.iter().filter(|&&f| f).count();
        let mut active: Vec<usize> = Vec::with_capacity(d.n_active);
        for i in 0..g.n_instances {
            if !failed_mask[i] && active.len() < d.n_active {
                active.push(i);
            }
        }
        if active.is_empty() {
            // A plan downing every board at once would strand admitted
            // work and deadlock the shutdown drain invariant; serve the
            // decision's set as if the last board refused to die.
            active.extend(0..d.n_active.clamp(1, g.n_instances));
        }
        for (i, s) in slice.shards.iter().enumerate() {
            s.set_failed(failed_mask[i]);
            s.set_gated(!active.contains(&i));
        }
        let mut cursor = 0usize;
        for (si, shard) in slice.shards.iter().enumerate() {
            if active.contains(&si) {
                continue;
            }
            for mut r in shard.drain_all() {
                let mut placed = false;
                for _ in 0..active.len() {
                    let t = active[cursor % active.len()];
                    cursor += 1;
                    match slice.shards[t].try_push(r) {
                        Ok(()) => {
                            placed = true;
                            break;
                        }
                        Err(back) => r = back,
                    }
                }
                if placed {
                    g.redispatched.inc();
                } else {
                    // Every active shard is full: return the request to
                    // its original shard (bound-free) and retry next
                    // epoch — never drop admitted work.
                    shard.push_unbounded(r);
                }
            }
        }
        g.failed_boards.store(n_failed as u64, Ordering::Relaxed);
        self.served_fr = d.freq_ratio;
        self.served_vcore = vcore_next;
        self.served_vbram = vbram_next;
        self.served_active = d.n_active;
        self.served_healthy = active.len();
        self.served_failed = n_failed;
        self.served_slow = cfg.faults.capacity_factor(gi, &active, next_epoch);
        self.served_batch = d.batch;
    }
}

/// One hand-off slot per group: the relinquishing node deposits the
/// [`GroupCc`] here *before* flipping the hosting bit, so a consumer that
/// observes the new topology version always finds the controller waiting.
pub(super) struct Handover {
    slots: Vec<Mutex<Option<GroupCc>>>,
}

impl Handover {
    /// One empty slot per group.
    pub(super) fn new(n_groups: usize) -> Handover {
        Handover { slots: (0..n_groups).map(|_| Mutex::new(None)).collect() }
    }

    /// Park a controller for the next hosting node.
    pub(super) fn deposit(&self, gi: usize, cc: GroupCc) {
        match self.slots[gi].lock() {
            Ok(mut s) => *s = Some(cc),
            Err(poisoned) => *poisoned.into_inner() = Some(cc),
        }
    }

    /// Claim a parked controller, if any.
    pub(super) fn take(&self, gi: usize) -> Option<GroupCc> {
        match self.slots[gi].lock() {
            Ok(mut s) => s.take(),
            Err(poisoned) => poisoned.into_inner().take(),
        }
    }

    /// Shutdown sweep: controllers deposited but never adopted (a move
    /// raced the shutdown flag) still owe their records and decisions.
    pub(super) fn drain(&self) -> Vec<GroupCc> {
        (0..self.slots.len()).filter_map(|gi| self.take(gi)).collect()
    }
}

/// Everything one node CC thread needs, bundled for the spawn.
pub(super) struct NodeCtx {
    /// Fleet configuration (clock, epoch, faults, migrations, rebalance).
    pub(super) cfg: FleetServingConfig,
    /// All groups' shared state, global order.
    pub(super) groups: Vec<Arc<GroupShared>>,
    /// All nodes (migration pushes into the destination's slice).
    pub(super) nodes: Vec<Arc<NodeShared>>,
    /// This CC's node id.
    pub(super) me: usize,
    /// The fleet map (single source of truth for placement).
    pub(super) store: Arc<TopologyStore>,
    /// Controller hand-off slots.
    pub(super) handover: Arc<Handover>,
    /// Shared metrics registry.
    pub(super) registry: Arc<Registry>,
    /// Shutdown flag.
    pub(super) stop: Arc<AtomicBool>,
    /// Artifact directory for the PJRT voltage-selector engine.
    pub(super) artifacts_dir: std::path::PathBuf,
}

/// Mutable per-thread CC state: which groups this node currently hosts
/// and their resolved gauge handles.
struct NodeCcState {
    hosted: Vec<Option<GroupCc>>,
    gauges: Vec<Option<GroupGauges>>,
    seen_version: u64,
    saturated: bool,
}

/// Spawn the node's CC thread. Registers the clock actor on the calling
/// thread (deterministic id order: after every worker, node-id order);
/// returns the controllers the node still hosts at shutdown.
pub(super) fn spawn_node_cc(ctx: NodeCtx) -> std::thread::JoinHandle<Vec<GroupCc>> {
    let label = if ctx.nodes.len() == 1 {
        "cc".to_string()
    } else {
        format!("{}:cc", ctx.nodes[ctx.me].name)
    };
    // Node CCs are control-domain actors: they run the cross-group epoch
    // barrier (adopt/migrate/rebalance), so the parallel engine must fence
    // every worker domain against them (DESIGN.md S24).
    let actor = ctx.cfg.clock.register_actor_in(&label, 0);
    // detlint: allow(thread-spawn) -- actor pre-registered above; the
    // thread attaches before touching simulated time
    std::thread::spawn(move || {
        let _actor = ActorScope::attach(&ctx.cfg.clock, actor);
        let engine = if ctx.cfg.selector_via_pjrt {
            Engine::open(&ctx.artifacts_dir).ok()
        } else {
            None
        };
        let n_groups = ctx.groups.len();
        let mut st = NodeCcState {
            hosted: (0..n_groups).map(|_| None).collect(),
            gauges: (0..n_groups).map(|_| None).collect(),
            seen_version: 0,
            saturated: false,
        };
        // Initial adoption, before the first epoch: take the groups the
        // topology starts on this node. No gating is applied — all
        // shards start in the legacy layout's state (hosted slices
        // open, replicas gated) and epoch 0 is served before any pass.
        st.seen_version = ctx.store.version();
        adopt_hosted(&ctx, &mut st, 0, false);
        let mut epoch = 0usize;
        while !ctx.stop.load(Ordering::Relaxed) {
            ctx.cfg.clock.sleep(ctx.cfg.epoch);
            // Refresh the placement cache by version (the DESIGN.md S21
            // topology-retrieval contract): adopt any group whose
            // hand-off landed here since the last pass.
            let v = ctx.store.version();
            if v != st.seen_version {
                st.seen_version = v;
                adopt_hosted(&ctx, &mut st, epoch, true);
            }
            // Scripted moves depart *before* this epoch's pass, so the
            // destination (when its CC runs later this same instant) can
            // decide for the epoch without a gap.
            let moves: Vec<_> = ctx.cfg.migrations.moves_at(epoch, ctx.me).copied().collect();
            for m in moves {
                relinquish(&ctx, &mut st.hosted, m.group, m.to);
            }
            for gi in 0..n_groups {
                if let Some(cc) = st.hosted[gi].as_mut() {
                    let node = &ctx.nodes[ctx.me];
                    let gauges = st.gauges[gi].get_or_insert_with(|| {
                        GroupGauges::resolve(
                            &ctx.registry,
                            &node.name,
                            &ctx.groups[gi].name,
                            ctx.nodes.len() == 1,
                        )
                    });
                    cc.run_epoch(
                        &ctx.groups[gi],
                        &node.slices[gi],
                        &ctx.cfg,
                        engine.as_ref(),
                        gauges,
                        epoch,
                    );
                }
            }
            rebalance(&ctx, &mut st);
            epoch += 1;
        }
        st.hosted.into_iter().flatten().collect()
    })
}

/// Adopt every group the topology hosts here whose controller is parked
/// in its hand-off slot. `apply_gating` re-applies the controller's
/// serving set to the local slice (mid-run adoption); the initial
/// adoption skips it to preserve the legacy all-open epoch 0.
fn adopt_hosted(ctx: &NodeCtx, st: &mut NodeCcState, epoch: usize, apply_gating: bool) {
    for gi in 0..ctx.groups.len() {
        if st.hosted[gi].is_some() || ctx.store.hosting_mask(gi) & (1u64 << ctx.me) == 0 {
            continue;
        }
        let Some(cc) = ctx.handover.take(gi) else { continue };
        let g = &ctx.groups[gi];
        let node = &ctx.nodes[ctx.me];
        let gauges = st.gauges[gi].get_or_insert_with(|| {
            GroupGauges::resolve(&ctx.registry, &node.name, &g.name, ctx.nodes.len() == 1)
        });
        // Seed (or re-seed) the published surface so reads between
        // adoption and the first local pass see the controller's current
        // state, never zeros.
        gauges.set(cc.last_margin, cc.last_predictor_idx as f64);
        if apply_gating {
            // Re-open the slice per the controller's serving set — the
            // pass-end gating logic, replayed against the local shards.
            let slice = &node.slices[gi];
            let failed_mask: Vec<bool> = (0..g.n_instances)
                .map(|i| ctx.cfg.faults.board_failed(gi, i, epoch))
                .collect();
            let mut active: Vec<usize> = Vec::with_capacity(cc.served_active);
            for i in 0..g.n_instances {
                if !failed_mask[i] && active.len() < cc.served_active {
                    active.push(i);
                }
            }
            if active.is_empty() {
                active.extend(0..cc.served_active.clamp(1, g.n_instances));
            }
            for (i, s) in slice.shards.iter().enumerate() {
                s.set_failed(failed_mask[i]);
                s.set_gated(!active.contains(&i));
            }
        }
        st.hosted[gi] = Some(cc);
    }
}

/// Hand group `gi` over to node `to`: flip the hosting bit (new submits
/// route to the destination), gate the local slice, drain its backlog
/// into the destination's shards (re-dispatch, never a drop), fold
/// uncounted arrivals into the controller's residual, and park the
/// controller for the destination to adopt. A stale move — the topology
/// no longer hosts the group here — is a silent no-op: the store, not
/// the plan, is the source of truth.
fn relinquish(ctx: &NodeCtx, hosted: &mut [Option<GroupCc>], gi: usize, to: usize) -> bool {
    if gi >= ctx.groups.len() || to >= ctx.nodes.len() || to == ctx.me {
        return false;
    }
    let Some(mut cc) = hosted[gi].take() else { return false };
    if ctx.store.migrate(gi, ctx.me, to).is_err() {
        // The topology disagrees (concurrent rebalance won the race);
        // keep serving — never strand a controller.
        hosted[gi] = Some(cc);
        return false;
    }
    let g = &ctx.groups[gi];
    let src = &ctx.nodes[ctx.me].slices[gi];
    let dst = &ctx.nodes[to].slices[gi];
    // Gate first so local workers stop claiming, then drain — the PR 6
    // gate + drain + re-dispatch machinery, pointed across nodes. A
    // wall-clock submit that read the old mask mid-flight can still land
    // on a gated source shard afterwards; it is not lost — shutdown
    // ungates every slice and the group-global drain invariant holds.
    for s in &src.shards {
        s.set_gated(true);
        s.set_failed(false);
    }
    let nd = dst.shards.len();
    let mut cursor = 0usize;
    for s in &src.shards {
        for mut r in s.drain_all() {
            let mut placed = false;
            for _ in 0..nd {
                let t = cursor % nd;
                cursor += 1;
                match dst.shards[t].try_push(r) {
                    Ok(()) => {
                        placed = true;
                        break;
                    }
                    Err(back) => r = back,
                }
            }
            if !placed {
                // Destination full across the board: unbounded fallback
                // keeps the request queued rather than dropped.
                dst.shards[cursor % nd].push_unbounded(r);
                cursor += 1;
            }
            g.redispatched.inc();
        }
    }
    // Arrivals counted here since the last pass travel with the
    // controller as a residual, so offered demand crosses the hand-off
    // intact (the predictor never sees a phantom dip).
    cc.residual_arrivals += src.arrivals_this_epoch.swap(0, Ordering::Relaxed);
    cc.sat_streak = 0;
    g.migrated.inc();
    ctx.handover.deposit(gi, cc);
    true
}

/// Opt-in auto-rebalancer (off by default — `cfg.rebalance: None` keeps
/// every legacy run untouched): a group whose modeled backlog stays at or
/// above the threshold for `sustain` consecutive epochs is migrated to
/// the least-loaded other node, and the node's health flag tracks whether
/// any hosted group is currently over the threshold.
fn rebalance(ctx: &NodeCtx, st: &mut NodeCcState) {
    let Some(rb) = &ctx.cfg.rebalance else { return };
    if ctx.nodes.len() < 2 {
        return;
    }
    let mut pending: Vec<usize> = Vec::new();
    for (gi, slot) in st.hosted.iter_mut().enumerate() {
        let Some(cc) = slot.as_mut() else { continue };
        if cc.backlog >= rb.min_backlog {
            cc.sat_streak += 1;
        } else {
            cc.sat_streak = 0;
        }
        if cc.sat_streak >= rb.sustain {
            pending.push(gi);
        }
    }
    let now_saturated = !pending.is_empty();
    if now_saturated != st.saturated {
        st.saturated = now_saturated;
        let health = if now_saturated { NodeHealth::Saturated } else { NodeHealth::Healthy };
        let _ = ctx.store.set_health(ctx.me, health);
    }
    for gi in pending {
        match router::pick_migration_target(&ctx.store, ctx.me) {
            Some(to) => {
                relinquish(ctx, &mut st.hosted, gi, to);
            }
            None => {
                if let Some(cc) = st.hosted[gi].as_mut() {
                    // Nowhere to go; restart the observation window
                    // instead of re-triggering every epoch.
                    cc.sat_streak = 0;
                }
            }
        }
    }
}

/// Route a submit within a slice: dispatcher pick, then a non-gated
/// fallback scan — the legacy single-process placement, verbatim.
pub(super) fn place_request(slice: &GroupSlice, mut req: Request) -> Result<(), SubmitError> {
    let first = slice.dispatcher.pick(&slice.shards);
    match slice.shards[first].try_push(req) {
        Ok(()) => Ok(()),
        Err(back) => {
            req = back;
            let n = slice.shards.len();
            for step in 1..n {
                let idx = (first + step) % n;
                // Gated shards' workers are parked; routing there would
                // strand the request until the next CC drain.
                if slice.shards[idx].is_gated() {
                    continue;
                }
                match slice.shards[idx].try_push(req) {
                    Ok(()) => return Ok(()),
                    Err(back) => req = back,
                }
            }
            Err(SubmitError::QueueFull)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reqs(n: usize) -> Vec<Request> {
        // Timestamps route through the injected clock; unit tests pin them
        // to tick 0 so no helper ever reads wall time mid-test.
        (0..n)
            .map(|i| Request { id: i as u64, payload: vec![0.0; 2], submitted: 0 })
            .collect()
    }

    #[test]
    fn claim_batch_steals_from_deepest_sibling_when_idle() {
        let shards: Vec<Arc<ShardQueue>> =
            (0..3).map(|_| Arc::new(ShardQueue::new(64))).collect();
        for r in reqs(8) {
            shards[0].try_push(r).unwrap();
        }
        for r in reqs(2) {
            shards[1].try_push(r).unwrap();
        }
        // Worker 2 is idle; it must steal ~half of shard 0's backlog.
        let (batch, stolen) = claim_batch(&shards, 2, 16, Duration::from_millis(1), true);
        assert!(stolen, "idle worker must steal");
        assert_eq!(batch.len(), 4);
        assert_eq!(shards[0].len(), 4);
        assert_eq!(shards[1].len(), 2, "shallower sibling untouched");
    }

    #[test]
    fn claim_batch_prefers_home_shard_and_respects_steal_flag() {
        let shards: Vec<Arc<ShardQueue>> =
            (0..2).map(|_| Arc::new(ShardQueue::new(64))).collect();
        for r in reqs(3) {
            shards[1].try_push(r).unwrap();
        }
        shards[0]
            .try_push(Request { id: 99, payload: vec![], submitted: 0 })
            .unwrap();
        let (batch, stolen) = claim_batch(&shards, 0, 16, Duration::from_millis(1), true);
        assert!(!stolen, "home work comes first");
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 99);

        // With stealing disabled the idle worker stays empty-handed.
        let (batch, stolen) = claim_batch(&shards, 0, 16, Duration::from_millis(1), false);
        assert!(!stolen);
        assert!(batch.is_empty());
        assert_eq!(shards[1].len(), 3);
    }

    #[test]
    fn claim_batch_never_steals_from_a_gated_sibling() {
        let shards: Vec<Arc<ShardQueue>> =
            (0..3).map(|_| Arc::new(ShardQueue::new(64))).collect();
        for r in reqs(8) {
            shards[1].try_push(r).unwrap();
        }
        shards[1].set_gated(true);
        for r in reqs(2) {
            shards[2].try_push(r).unwrap();
        }
        // Worker 0 is idle; the deepest shard is gated, so it must steal
        // from the shallower active sibling instead.
        let (batch, stolen) = claim_batch(&shards, 0, 16, Duration::from_millis(1), true);
        assert!(stolen);
        assert_eq!(batch.len(), 1, "steals half of the active sibling's 2");
        assert_eq!(shards[1].len(), 8, "gated backlog is left for the CC drain");
    }

    #[test]
    fn place_request_skips_gated_shards_and_reports_backpressure() {
        let slice = GroupSlice {
            shards: (0..3).map(|_| Arc::new(ShardQueue::new(1))).collect(),
            dispatcher: Dispatcher::new(super::super::dispatch::DispatchPolicy::RoundRobin),
            arrivals_this_epoch: AtomicU64::new(0),
        };
        // Fill shard 0 (the round-robin first pick) and gate shard 1;
        // the fallback scan must land the request on shard 2.
        slice.shards[0]
            .try_push(Request { id: 0, payload: vec![], submitted: 0 })
            .unwrap();
        slice.shards[1].set_gated(true);
        place_request(&slice, Request { id: 1, payload: vec![], submitted: 0 }).unwrap();
        assert_eq!(slice.shards[2].len(), 1);
        // Fill shard 2 as well: only the gated shard has room, and the
        // scan must refuse it with typed backpressure.
        slice.shards[2]
            .try_push(Request { id: 2, payload: vec![], submitted: 0 })
            .unwrap();
        let err = place_request(&slice, Request { id: 3, payload: vec![], submitted: 0 });
        assert_eq!(err, Err(SubmitError::QueueFull));
        assert_eq!(slice.shards[1].len(), 0, "gated shard never receives submits");
    }

    #[test]
    fn handover_slots_park_take_and_drain() {
        // Empty-slot behavior; the tests below cover real controllers.
        let h = Handover::new(2);
        assert!(h.take(0).is_none());
        assert!(h.drain().is_empty());
    }

    /// Full platform build for one benchmark — the same construction the
    /// fleet runs per group, shrunk to test scale (netlist at 5%).
    fn built_platform(bench: &str) -> (DesignPower, Optimizer) {
        use crate::arch::{BenchmarkSpec, DeviceFamily};
        use crate::chars::CharLibrary;
        use crate::netlist::gen::{generate, GenConfig};
        use crate::power::PowerParams;
        use crate::sta::{analyze, DelayParams};

        let chars = CharLibrary::stratix_iv_22nm();
        let spec = BenchmarkSpec::by_name(bench).unwrap();
        let design = DesignPower::from_spec(
            spec,
            &DeviceFamily::stratix_iv(),
            chars.clone(),
            PowerParams::default(),
        )
        .unwrap();
        let net = generate(spec, &GenConfig { scale: 0.05, seed: 2019, luts_per_lab: 10 });
        let rep = analyze(&net, &DelayParams::default(), 8).unwrap();
        let optimizer = Optimizer::new(chars.grid(), design.rail_tables(&rep.cp))
            .with_paths(&chars, rep.top_paths.clone());
        (design, optimizer)
    }

    fn shared_for(cfg: &FleetServingConfig, gi: usize) -> GroupShared {
        use crate::metrics::{Counter, Histogram};

        let g = &cfg.groups[gi];
        GroupShared {
            name: g.benchmark.clone(),
            share: g.share,
            n_instances: g.n_instances,
            backend_name: "native",
            in_dim: 8,
            out_dim: 4,
            batch: 16,
            batch_now: AtomicU64::new(cfg.batch_nominal.max(1) as u64),
            freq_ratio: AtomicU64::new(1.0f64.to_bits()),
            vcore_mv: AtomicU64::new(800),
            vbram_mv: AtomicU64::new(950),
            active_now: AtomicU64::new(g.n_instances as u64),
            margin_now: AtomicU64::new(cfg.margin_t.to_bits()),
            predictor_now: AtomicU64::new(0),
            admitted: Counter::default(),
            completed: Counter::default(),
            rejected: Counter::default(),
            failed: Counter::default(),
            stolen_batches: Counter::default(),
            redispatched: Counter::default(),
            migrated: Counter::default(),
            failed_boards: AtomicU64::new(0),
            violations: Counter::default(),
            epochs: Counter::default(),
            latency_us: Histogram::latency_us(),
            energy_j: Gauge::default(),
            nominal_energy_j: Gauge::default(),
        }
    }

    /// A real `GroupCc` — full controller, LUT family, operating point —
    /// not a stand-in, so the hand-off tests below move the same object
    /// migrations do.
    fn real_cc(gi: usize, cfg: &FleetServingConfig) -> GroupCc {
        let (design, optimizer) = built_platform(&cfg.groups[gi].benchmark);
        GroupCc::new(gi, design, optimizer, cfg, &shared_for(cfg, gi))
    }

    #[test]
    fn handover_drain_returns_unadopted_controllers_with_their_state() {
        let mut cfg = FleetServingConfig::default();
        cfg.groups.push(cfg.groups[0].clone());
        let h = Handover::new(cfg.groups.len());

        let cc0 = real_cc(0, &cfg);
        let mut cc1 = real_cc(1, &cfg);
        // State the next hosting node must resume from: pretend cc1 was
        // mid-saturation when its node relinquished it.
        cc1.backlog = 7.5;
        cc1.sat_streak = 3;
        h.deposit(0, cc0);
        h.deposit(1, cc1);

        let adopted = h.take(0).expect("a deposited controller is claimable");
        assert_eq!(adopted.gi, 0);
        assert!(h.take(0).is_none(), "a controller is adopted at most once");

        // Shutdown raced the move: the adopter re-parks cc0 and exits.
        // The sweep must return every parked controller, state intact.
        h.deposit(0, adopted);
        let drained = h.drain();
        assert_eq!(drained.iter().map(|c| c.gi).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(
            drained[1].backlog, 7.5,
            "modeled backlog must travel with the controller"
        );
        assert_eq!(drained[1].sat_streak, 3);
        assert!(h.drain().is_empty(), "the sweep leaves every slot empty");
    }

    /// Hand-off slots recover from poisoning: a CC panicking mid-move
    /// must not strand (or lose) another group's controller. Std mutexes
    /// only — the loom shim's mutex has no poisoning.
    #[test]
    #[cfg(not(loom))]
    fn handover_slots_recover_from_poisoning() {
        let cfg = FleetServingConfig::default();
        let h = Arc::new(Handover::new(1));

        let hc = Arc::clone(&h);
        // detlint: allow(thread-spawn) -- poisoning test; no simulated time
        let panicked = std::thread::spawn(move || {
            let _guard = hc.slots[0].lock().unwrap();
            panic!("simulated CC panic during a hand-off");
        })
        .join();
        assert!(panicked.is_err());

        h.deposit(0, real_cc(0, &cfg));
        let cc = h.take(0).expect("a poisoned slot still hands the controller over");
        assert_eq!(cc.gi, 0);
        assert!(h.drain().is_empty());
    }
}
