//! Shard selection on the submit path (DESIGN.md S11.3).
//!
//! With per-instance shard queues the submitter must pick a shard per
//! request. Two policies:
//!
//! * [`DispatchPolicy::RoundRobin`] — one atomic increment, perfectly fair
//!   under uniform service times;
//! * [`DispatchPolicy::LeastLoaded`] — scan the relaxed depth mirrors and
//!   pick the shallowest shard (join-the-shortest-queue), which adapts to
//!   stragglers at the cost of an O(shards) read-only scan.
//!
//! Both are lock-free; work stealing on the worker side covers whatever
//! imbalance the policy leaves behind.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::shard::ShardQueue;

/// How the submit path spreads requests over a group's shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Rotate through shards with an atomic cursor.
    RoundRobin,
    /// Join the shortest queue using the shards' lock-free depths.
    LeastLoaded,
}

impl DispatchPolicy {
    /// Human-readable policy name (CLI / reports).
    pub fn name(self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round-robin",
            DispatchPolicy::LeastLoaded => "least-loaded",
        }
    }
}

/// Stateful shard picker shared by all submitters of one group.
#[derive(Debug)]
pub struct Dispatcher {
    policy: DispatchPolicy,
    cursor: AtomicUsize,
}

impl Dispatcher {
    /// Build a dispatcher for the given policy.
    pub fn new(policy: DispatchPolicy) -> Self {
        Dispatcher { policy, cursor: AtomicUsize::new(0) }
    }

    /// The configured policy.
    pub fn policy(&self) -> DispatchPolicy {
        self.policy
    }

    /// Choose a shard index for the next request.
    pub fn pick(&self, shards: &[Arc<ShardQueue>]) -> usize {
        debug_assert!(!shards.is_empty());
        match self.policy {
            DispatchPolicy::RoundRobin => {
                self.cursor.fetch_add(1, Ordering::Relaxed) % shards.len()
            }
            DispatchPolicy::LeastLoaded => {
                let mut best = 0usize;
                let mut best_depth = usize::MAX;
                for (i, s) in shards.iter().enumerate() {
                    let d = s.len();
                    if d < best_depth {
                        best_depth = d;
                        best = i;
                    }
                }
                best
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Request;
    use std::time::Instant;

    fn shards(n: usize) -> Vec<Arc<ShardQueue>> {
        (0..n).map(|_| Arc::new(ShardQueue::new(64))).collect()
    }

    fn req(id: u64) -> Request {
        Request { id, payload: vec![], submitted: Instant::now() }
    }

    #[test]
    fn round_robin_cycles() {
        let s = shards(3);
        let d = Dispatcher::new(DispatchPolicy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|_| d.pick(&s)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(d.policy().name(), "round-robin");
    }

    #[test]
    fn least_loaded_picks_shallowest() {
        let s = shards(3);
        for i in 0..4 {
            s[0].try_push(req(i)).unwrap();
        }
        s[1].try_push(req(9)).unwrap();
        let d = Dispatcher::new(DispatchPolicy::LeastLoaded);
        assert_eq!(d.pick(&s), 2, "empty shard must win");
        s[2].try_push(req(10)).unwrap();
        s[2].try_push(req(11)).unwrap();
        assert_eq!(d.pick(&s), 1, "now shard 1 is shallowest");
    }
}
