//! Shard selection on the submit path (DESIGN.md S11.3).
//!
//! With per-instance shard queues the submitter must pick a shard per
//! request. Two policies:
//!
//! * [`DispatchPolicy::RoundRobin`] — one atomic increment, perfectly fair
//!   under uniform service times;
//! * [`DispatchPolicy::LeastLoaded`] — scan the relaxed depth mirrors and
//!   pick the shallowest shard (join-the-shortest-queue), which adapts to
//!   stragglers at the cost of an O(shards) read-only scan.
//!
//! Both are lock-free; work stealing on the worker side covers whatever
//! imbalance the policy leaves behind.
//!
//! In a multi-node fleet each node's slice has its own dispatcher: the
//! cross-node hop (which node's slice receives the submit) is decided one
//! layer up by `coordinator::router` from the topology's hosting masks,
//! and this policy then places the request within the chosen node.

use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::Arc;

use super::shard::ShardQueue;

/// How the submit path spreads requests over a group's shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Rotate through shards with an atomic cursor.
    RoundRobin,
    /// Join the shortest queue using the shards' lock-free depths.
    LeastLoaded,
}

impl DispatchPolicy {
    /// Human-readable policy name (CLI / reports).
    pub fn name(self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round-robin",
            DispatchPolicy::LeastLoaded => "least-loaded",
        }
    }
}

/// Stateful shard picker shared by all submitters of one group.
#[derive(Debug)]
pub struct Dispatcher {
    policy: DispatchPolicy,
    cursor: AtomicUsize,
}

impl Dispatcher {
    /// Build a dispatcher for the given policy.
    pub fn new(policy: DispatchPolicy) -> Self {
        Dispatcher { policy, cursor: AtomicUsize::new(0) }
    }

    /// The configured policy.
    pub fn policy(&self) -> DispatchPolicy {
        self.policy
    }

    /// Last-resort pick when a racy gated-flag scan came up empty: re-scan
    /// once and prefer *any* ungated shard over the blind shard-0
    /// fallback. Under a board-0 failure plan the old unconditional
    /// fallback routed the racing submit onto the failed shard, stranding
    /// it until the next CC epoch drain; shard 0 is now chosen only when
    /// the re-scan confirms every shard reads gated (the CC never gates
    /// all instances, so that state is itself a transient race).
    fn fallback(shards: &[Arc<ShardQueue>]) -> usize {
        shards.iter().position(|s| !s.is_gated()).unwrap_or(0)
    }

    /// Choose a shard index for the next request. Gated shards (elastic
    /// capacity manager, DESIGN.md S6.1) are skipped — their worker is
    /// parked, so routing to them would strand the request until the next
    /// CC drain. The gated flags are read racily; when a scan comes up
    /// empty the pick re-scans once ([`Dispatcher::fallback`]) before
    /// settling on shard 0.
    pub fn pick(&self, shards: &[Arc<ShardQueue>]) -> usize {
        debug_assert!(!shards.is_empty());
        match self.policy {
            DispatchPolicy::RoundRobin => {
                // Rotate over the *active* shards only: advancing past a
                // gated run would funnel every pick that lands in it onto
                // the next active shard, skewing its queue depth.
                let active = shards.iter().filter(|s| !s.is_gated()).count();
                if active == 0 {
                    return Self::fallback(shards);
                }
                let k = self.cursor.fetch_add(1, Ordering::Relaxed) % active;
                shards
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| !s.is_gated())
                    .nth(k)
                    .map(|(i, _)| i)
                    // Gating flags moved between count and scan: take any
                    // still-active shard rather than blind shard 0.
                    .unwrap_or_else(|| Self::fallback(shards))
            }
            DispatchPolicy::LeastLoaded => {
                let mut best = None;
                let mut best_depth = usize::MAX;
                for (i, s) in shards.iter().enumerate() {
                    if s.is_gated() {
                        continue;
                    }
                    let d = s.len();
                    if d < best_depth {
                        best_depth = d;
                        best = Some(i);
                    }
                }
                best.unwrap_or_else(|| Self::fallback(shards))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Request;

    fn shards(n: usize) -> Vec<Arc<ShardQueue>> {
        (0..n).map(|_| Arc::new(ShardQueue::new(64))).collect()
    }

    fn req(id: u64) -> Request {
        // Timestamps flow through the injected clock (DESIGN.md S18); unit
        // tests pin them to tick 0 so latency math never reads wall time.
        Request { id, payload: vec![], submitted: 0 }
    }

    #[test]
    fn round_robin_cycles() {
        let s = shards(3);
        let d = Dispatcher::new(DispatchPolicy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|_| d.pick(&s)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(d.policy().name(), "round-robin");
    }

    #[test]
    fn both_policies_skip_gated_shards() {
        let s = shards(3);
        s[1].set_gated(true);
        let rr = Dispatcher::new(DispatchPolicy::RoundRobin);
        let picks: Vec<usize> = (0..4).map(|_| rr.pick(&s)).collect();
        assert!(!picks.contains(&1), "round-robin must skip the gated shard: {picks:?}");

        // Least-loaded: the gated shard is empty (cheapest) but skipped.
        s[0].try_push(req(0)).unwrap();
        s[2].try_push(req(1)).unwrap();
        s[2].try_push(req(2)).unwrap();
        let ll = Dispatcher::new(DispatchPolicy::LeastLoaded);
        assert_eq!(ll.pick(&s), 0);
        // All gated: fall back to shard 0 rather than failing.
        s[0].set_gated(true);
        s[2].set_gated(true);
        assert_eq!(ll.pick(&s), 0);
        assert_eq!(rr.pick(&s), 0);
    }

    #[test]
    fn pick_avoids_the_failed_board_under_the_canonical_board_0_plan() {
        use crate::workload::FaultPlan;

        // The canonical board-failure plan over a single-instance layout
        // fails board 0 for the middle third of the run; mirror the CC's
        // gate pass onto shard 0 of a 3-shard group.
        let plan = FaultPlan::for_scenario("board-failure", 1, 1, 48);
        let mid_epoch = 24;
        assert!(plan.board_failed(0, 0, mid_epoch), "canonical plan must fail board 0");
        let s = shards(3);
        s[0].set_failed(true);
        s[0].set_gated(true);

        // Deterministic: neither policy may route onto the failed board.
        for d in [
            Dispatcher::new(DispatchPolicy::RoundRobin),
            Dispatcher::new(DispatchPolicy::LeastLoaded),
        ] {
            for _ in 0..32 {
                assert_ne!(d.pick(&s), 0, "{}: picked the failed board", d.policy().name());
            }
        }

        // Racy: a CC-like thread toggles shard 1's gate while submits
        // race it. The old empty-scan fallback returned shard 0 — the
        // failed board — whenever the gated-flag count and scan straddled
        // a toggle; the re-scan fallback must always land on an ungated
        // sibling instead (shard 2 stays active throughout).
        let stop = Arc::new(crate::sync::atomic::AtomicBool::new(false));
        let (s2, stop2) = (s.clone(), stop.clone());
        // detlint: allow(thread-spawn) -- race-stress test; no simulated time
        let toggler = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                s2[1].set_gated(true);
                s2[1].set_gated(false);
            }
        });
        let rr = Dispatcher::new(DispatchPolicy::RoundRobin);
        for _ in 0..5000 {
            let pick = rr.pick(&s);
            assert_ne!(pick, 0, "round-robin raced onto the failed board");
        }
        stop.store(true, Ordering::Relaxed);
        toggler.join().unwrap();
    }

    #[test]
    fn least_loaded_picks_shallowest() {
        let s = shards(3);
        for i in 0..4 {
            s[0].try_push(req(i)).unwrap();
        }
        s[1].try_push(req(9)).unwrap();
        let d = Dispatcher::new(DispatchPolicy::LeastLoaded);
        assert_eq!(d.pick(&s), 2, "empty shard must win");
        s[2].try_push(req(10)).unwrap();
        s[2].try_push(req(11)).unwrap();
        assert_eq!(d.pick(&s), 1, "now shard 1 is shallowest");
    }
}
