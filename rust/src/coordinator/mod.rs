//! Serving coordinator — the paper's platform as a live request path
//! (DESIGN.md S11).
//!
//! Layer-3 topology (Fig. 9 adapted to a serving framework):
//!   * per-instance bounded **shard queues** over a lock-free MPMC ring
//!     (DESIGN.md S22) with exact depth mirrors, least-loaded/round-robin
//!     dispatch and work stealing on idle workers (DESIGN.md S11.2–S11.3)
//!     — the old single global `Mutex<VecDeque>` queue is gone,
//!   * one worker thread per simulated FPGA instance, each executing the
//!     benchmark's AOT-compiled DNN artifact through its own PJRT client —
//!     or the deterministic native backend when PJRT/artifacts are absent
//!     (DESIGN.md S11.4),
//!   * a Central Controller (CC) epoch loop per fleet: for every tenant
//!     group and DVFS epoch it reads the arrival counter, updates that
//!     group's Markov predictor, picks the frequency bin, queries the
//!     Voltage Selector (the AOT'd Pallas artifact via PJRT — or the
//!     native optimizer as fallback), and publishes the
//!     (freq_ratio, Vcore, Vbram) the group's workers honour next epoch.
//!
//! Multi-tenant serving lives in [`fleet::FleetServing`]: several
//! benchmark groups (Tabla + DianNao + ...) share one coordinator, each
//! with its own predictor, voltage LUT and DVFS domain, reported through a
//! shared fleet-level metrics surface (DESIGN.md S11.5). [`Coordinator`]
//! is the single-tenant facade over a one-group fleet, kept for the
//! simple serve path and the perf benches.
//!
//! The fleet itself is composed from three layers (DESIGN.md S21):
//! [`topology`] — the versioned pure-data map of groups → nodes → shards
//! behind a [`TopologyStore`]; [`node`](self) agents — per-node data
//! planes plus a CC thread running the shared
//! [`GroupController`](crate::control::GroupController) loop per hosted
//! group; and a [`router`](self) that places submits on the hosting node
//! and (opt-in, [`RebalanceConfig`]) migrates groups off saturated
//! nodes. A `nodes: 1` fleet — the default — is the legacy single-process
//! coordinator, bit-identical.
//!
//! The FPGA's *service rate* is simulated: a batch occupies its instance
//! for `cycles / (f_nom · freq_ratio)`; the numeric inference itself is
//! real execution. Energy is integrated from the power model at the
//! operating point of each epoch. Rust threads + channels only — no
//! external runtime (DESIGN.md §6).
//!
//! The CC runs the **elastic capacity manager** (DESIGN.md S6.1) by
//! default: each epoch it picks the minimum-power (active instances,
//! Vcore, Vbram, f) combination from the per-group
//! [`ElasticLut`](crate::vscale::ElasticLut); gated instances' shards are
//! skipped by dispatch and stealing, their workers park on the shard
//! condvar, and their queued requests are drained into active shards.
//!
//! Every time-shaped operation — worker waits, the CC epoch loop, service
//! occupancy, request timestamps — goes through the injected
//! [`Clock`](crate::clock::Clock) (DESIGN.md S18). The default
//! `WallClock` preserves the live behavior; a
//! [`VirtualClock`](crate::clock::VirtualClock) turns the whole
//! coordinator into a deterministic discrete-event simulation
//! (`simtest`): thousand-epoch scenarios replay in milliseconds and two
//! runs with the same seed produce byte-identical epoch traces.
//!
//! This module is the user-facing serving API: it must return typed
//! errors under bad input or load, never abort the process, so panicking
//! constructs are denied lint-level for all non-test code below.

#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::panic))]

pub mod backend;
pub mod dispatch;
pub mod fleet;
mod node;
mod router;
pub mod shard;
pub mod topology;

pub use backend::{variant_dims, InferenceBackend, NativeDnn};
pub use dispatch::{DispatchPolicy, Dispatcher};
pub use fleet::{
    drive_scenario, fleet_report_rows, ConfigError, FleetServing, FleetServingConfig,
    FleetServingReport, FleetServingStats, GroupConfig, GroupServingStats,
};
pub use router::RebalanceConfig;
pub use shard::ShardQueue;
pub use topology::{
    FleetTopology, MigrationPlan, NodeHealth, NodeInfo, ScriptedMigration, TopologyError,
    TopologySnapshot, TopologyStore, MAX_NODES,
};

use std::time::Duration;

use crate::sync::Arc;

use anyhow::Result;

use crate::clock::{self, Clock, Tick};
use crate::markov::PredictorKind;
use crate::power::DesignPower;
use crate::vscale::{CapacityPolicy, Mode, Optimizer};

/// Single-tenant coordinator configuration.
#[derive(Clone, Debug)]
pub struct ServingConfig {
    /// Benchmark / artifact variant (tabla, dnnweaver, ...).
    pub variant: String,
    /// Number of simulated FPGA instances (worker threads == shards).
    pub n_instances: usize,
    /// DVFS epoch length (the simulator's τ, compressed for serving runs).
    pub epoch: Duration,
    /// Max requests queued before submit() applies backpressure (split
    /// evenly across the per-instance shards).
    pub queue_capacity: usize,
    /// Max wait to fill a batch before dispatching a partial one.
    pub batch_timeout: Duration,
    /// Cycles one batch occupies an instance (service time = cycles / f).
    pub cycles_per_batch: f64,
    /// Voltage mode for the CC.
    pub mode: Mode,
    /// Use the AOT'd Pallas Voltage Selector through PJRT (true) or the
    /// native optimizer (false).
    pub selector_via_pjrt: bool,
    /// Markov bins for the workload predictor.
    pub m_bins: usize,
    /// Throughput margin t for the voltage LUT.
    pub margin_t: f64,
    /// Pure-training epochs before predictions are trusted.
    pub warmup_epochs: usize,
    /// Shard selection policy on the submit path.
    pub dispatch: DispatchPolicy,
    /// Allow idle workers to steal from sibling shards.
    pub steal: bool,
    /// How the CC trades instance gating against DVFS each epoch
    /// (DESIGN.md S6.1); `Hybrid` is the elastic capacity manager.
    pub capacity_policy: CapacityPolicy,
    /// Residual power fraction (of nominal) drawn by a gated instance.
    pub pg_residual: f64,
    /// Workload predictor driving the CC (DESIGN.md S7).
    pub predictor: PredictorKind,
    /// Epochs per cycle assumed by the periodic predictor member.
    pub predictor_period: usize,
    /// `Some(target)` enables the adaptive QoS-feedback guardband
    /// (DESIGN.md S7.1); `None` keeps the static `margin_t`.
    pub qos_target: Option<f64>,
    /// Time source for every wait/sleep/timestamp (DESIGN.md S18):
    /// `clock::wall()` for live serving, a `VirtualClock` for
    /// deterministic simulation.
    pub clock: Arc<dyn Clock>,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            variant: "tabla".into(),
            n_instances: 2,
            epoch: Duration::from_millis(200),
            queue_capacity: 4096,
            batch_timeout: Duration::from_millis(5),
            cycles_per_batch: 2.0e5,
            mode: Mode::Proposed,
            selector_via_pjrt: true,
            m_bins: 10,
            margin_t: 0.05,
            warmup_epochs: 2,
            dispatch: DispatchPolicy::LeastLoaded,
            steal: true,
            capacity_policy: CapacityPolicy::Hybrid,
            pg_residual: 0.02,
            predictor: PredictorKind::Markov,
            predictor_period: 96,
            qos_target: None,
            clock: clock::wall(),
        }
    }
}

/// One inference request.
#[derive(Debug)]
pub struct Request {
    /// Monotonic id assigned at submit time.
    pub id: u64,
    /// Input features (`in_dim` floats).
    pub payload: Vec<f32>,
    /// Submit timestamp on the fleet's clock (end-to-end latency
    /// reference; a virtual tick under `VirtualClock`, so latency
    /// accounting stays exact in simulated runs).
    pub submitted: Tick,
}

/// Completed request record.
#[derive(Clone, Debug)]
pub struct Completion {
    /// Request id.
    pub id: u64,
    /// Worker instance that served the request.
    pub worker: usize,
    /// End-to-end latency.
    pub latency: Duration,
    /// First output logit (proof of real compute).
    pub y0: f32,
}

/// Typed error of the submit path. The serving API applies
/// backpressure-style errors instead of aborting the process: an unknown
/// tenant or a malformed payload is the *caller's* bug and must surface
/// as an `Err` they can handle, never as a panic inside the coordinator.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// No group serves the requested benchmark name / group index.
    UnknownGroup(String),
    /// The payload length does not match the group's model input width.
    BadPayload {
        /// Input feature width the group's model expects.
        expected: usize,
        /// Float count the caller actually supplied.
        got: usize,
    },
    /// Every active shard of the group is at capacity (backpressure).
    QueueFull,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::UnknownGroup(who) => write!(f, "no group serves {who}"),
            SubmitError::BadPayload { expected, got } => {
                write!(f, "payload must be {expected} floats, got {got}")
            }
            SubmitError::QueueFull => write!(f, "every active shard is at capacity"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Aggregate serving statistics of a single-tenant coordinator.
#[derive(Clone, Debug)]
pub struct ServingStats {
    /// Requests served to completion.
    pub completed: u64,
    /// Requests refused by backpressure.
    pub rejected: u64,
    /// Requests dropped because the inference backend errored.
    pub failed: u64,
    /// Batches obtained by work stealing.
    pub stolen_batches: u64,
    /// Inference backend in use (`pjrt` or `native`).
    pub backend: &'static str,
    /// Mean end-to-end latency (s).
    pub mean_latency_s: f64,
    /// Median end-to-end latency (s).
    pub p50_latency_s: f64,
    /// 99th-percentile end-to-end latency (s).
    pub p99_latency_s: f64,
    /// Energy integrated at the CC's operating points (J).
    pub energy_j: f64,
    /// Energy a nominal-V/f platform would have drawn (J).
    pub nominal_energy_j: f64,
    /// Paper's headline metric: nominal energy / actual energy.
    pub power_gain: f64,
    /// Fraction of epochs whose demand exceeded served capacity.
    pub violation_rate: f64,
    /// DVFS epochs elapsed.
    pub epochs: usize,
    /// Currently published f / f_nom.
    pub freq_ratio_now: f64,
    /// Currently published core-rail voltage (V).
    pub vcore_now: f64,
    /// Currently published BRAM-rail voltage (V).
    pub vbram_now: f64,
    /// Instances currently active (not gated by the elastic manager).
    pub active_now: usize,
    /// Throughput margin currently applied by the CC (static `margin_t`
    /// or the adaptive guardband's ladder level).
    pub margin_now: f64,
    /// Prediction source currently active (the ensemble reports its
    /// member).
    pub predictor_now: &'static str,
}

/// Per-epoch CC trace row.
///
/// The decision columns live in the embedded
/// [`DecisionRecord`](crate::control::DecisionRecord) — shared with the
/// offline `platform::StepRecord` so the two trace formats cannot drift
/// — and are reachable directly through `Deref` (`rec.freq_ratio`,
/// `rec.margin`, ...). Alignment matches `StepRecord` exactly:
/// `freq_ratio`/`vcore`/`vbram`/`n_active` are the operating point that
/// *served* this epoch (published at the end of the previous one), and
/// `predicted`/`predictor`/`margin` come from the decision *made* this
/// epoch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EpochRecord {
    /// Epoch index.
    pub epoch: usize,
    /// Normalized load observed over the epoch.
    pub load: f64,
    /// Shared decision columns (see the struct-level note on alignment).
    pub decision: crate::control::DecisionRecord,
    /// Group power at the serving operating point (W).
    pub power_w: f64,
    /// Boards failed (fault-plan injected, DESIGN.md S20) while the
    /// epoch was served. Always 0 on an empty plan.
    pub n_failed: usize,
    /// Mean straggler service-rate factor of the set that served the
    /// epoch; exactly `1.0` when no straggler window overlaps.
    pub slow_factor: f64,
}

impl std::ops::Deref for EpochRecord {
    type Target = crate::control::DecisionRecord;

    fn deref(&self) -> &crate::control::DecisionRecord {
        &self.decision
    }
}

/// Single-tenant serving coordinator: a one-group [`FleetServing`].
pub struct Coordinator {
    /// Configuration the coordinator was started with.
    pub cfg: ServingConfig,
    inner: FleetServing,
    /// Input feature width of the served model.
    pub in_dim: usize,
    /// Requests per inference dispatch.
    pub batch: usize,
}

impl Coordinator {
    /// Start workers + CC. `artifacts_dir` should contain `make artifacts`
    /// output (the native backend is used when it does not);
    /// `design`/`optimizer` come from the platform build.
    pub fn start(
        cfg: ServingConfig,
        artifacts_dir: std::path::PathBuf,
        design: DesignPower,
        optimizer: Optimizer,
    ) -> Result<Self> {
        // Batch-knob fields keep their fleet defaults: the single-tenant
        // facade predates the adaptive-batch controller and stays on the
        // fixed nominal geometry (DESIGN.md S22).
        let batch_defaults = FleetServingConfig::default();
        let fleet_cfg = FleetServingConfig {
            groups: vec![GroupConfig {
                benchmark: cfg.variant.clone(),
                share: 1.0,
                n_instances: cfg.n_instances,
                qos_target: None,
            }],
            epoch: cfg.epoch,
            queue_capacity: cfg.queue_capacity,
            batch_timeout: cfg.batch_timeout,
            cycles_per_batch: cfg.cycles_per_batch,
            batch_nominal: batch_defaults.batch_nominal,
            adaptive_batch: batch_defaults.adaptive_batch,
            batch_overhead: batch_defaults.batch_overhead,
            mode: cfg.mode,
            selector_via_pjrt: cfg.selector_via_pjrt,
            m_bins: cfg.m_bins,
            margin_t: cfg.margin_t,
            warmup_epochs: cfg.warmup_epochs,
            dispatch: cfg.dispatch,
            steal: cfg.steal,
            capacity_policy: cfg.capacity_policy,
            pg_residual: cfg.pg_residual,
            max_backlog_steps: 1.0,
            predictor: cfg.predictor,
            predictor_period: cfg.predictor_period,
            qos_target: cfg.qos_target,
            faults: Arc::new(crate::workload::FaultPlan::default()),
            nodes: 1,
            migrations: Arc::new(MigrationPlan::default()),
            rebalance: None,
            clock: cfg.clock.clone(),
        };
        let inner = FleetServing::start_with(fleet_cfg, artifacts_dir, vec![(design, optimizer)])?;
        let in_dim = inner.in_dim(0);
        let batch = inner.batch(0);
        Ok(Coordinator { cfg, inner, in_dim, batch })
    }

    /// Submit one request; `Err(SubmitError::QueueFull)` signals
    /// backpressure, `Err(SubmitError::BadPayload { .. })` a payload
    /// whose length is not `in_dim`.
    pub fn submit(&self, payload: Vec<f32>) -> std::result::Result<u64, SubmitError> {
        self.inner.submit(0, payload)
    }

    /// Requests currently queued across all shards.
    pub fn queue_len(&self) -> usize {
        self.inner.queue_len(0)
    }

    /// The underlying one-group fleet (shard metrics, registry, ...).
    pub fn fleet(&self) -> &FleetServing {
        &self.inner
    }

    fn map_stats(g: &GroupServingStats) -> ServingStats {
        ServingStats {
            completed: g.completed,
            rejected: g.rejected,
            failed: g.failed,
            stolen_batches: g.stolen_batches,
            backend: g.backend,
            mean_latency_s: g.mean_latency_s,
            p50_latency_s: g.p50_latency_s,
            p99_latency_s: g.p99_latency_s,
            energy_j: g.energy_j,
            nominal_energy_j: g.nominal_energy_j,
            power_gain: g.power_gain,
            violation_rate: g.violation_rate,
            epochs: g.epochs as usize,
            freq_ratio_now: g.freq_ratio_now,
            vcore_now: g.vcore_now,
            vbram_now: g.vbram_now,
            active_now: g.active_now,
            margin_now: g.margin_now,
            predictor_now: g.predictor_now,
        }
    }

    /// Live statistics snapshot.
    pub fn stats(&self) -> ServingStats {
        Self::map_stats(&self.inner.stats().per_group[0])
    }

    /// Stop accepting work, drain, join workers, and return the CC trace.
    pub fn shutdown(self) -> Result<(ServingStats, Vec<EpochRecord>)> {
        let report = self.inner.shutdown()?;
        let mut stats = Self::map_stats(&report.stats.per_group[0]);
        let records = report.epoch_records.into_iter().next().unwrap_or_default();
        stats.epochs = records.len();
        Ok((stats, records))
    }
}
