//! Serving coordinator — the paper's platform as a live request path
//! (DESIGN.md S11).
//!
//! Layer-3 topology (Fig. 9 adapted to a serving framework):
//!   * a bounded central request queue with backpressure,
//!   * one worker thread per simulated FPGA instance, each executing the
//!     benchmark's AOT-compiled DNN artifact through its own PJRT client
//!     (batch formation: up to the artifact batch, bounded wait),
//!   * a Central Controller (CC) epoch loop: per DVFS epoch it reads the
//!     arrival counter, updates the Markov predictor, picks the frequency
//!     bin, queries the Voltage Selector (the AOT'd Pallas artifact via
//!     PJRT — or the native optimizer as fallback), and publishes the
//!     (freq_ratio, Vcore, Vbram) the workers honour next epoch.
//!
//! The FPGA's *service rate* is simulated: a batch occupies its instance
//! for `cycles / (f_nom · freq_ratio)`; the numeric inference itself is
//! real PJRT execution. Energy is integrated from the power model at the
//! operating point of each epoch. Rust threads + channels only — no
//! external runtime (DESIGN.md §6).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::markov::{MarkovPredictor, Predictor};
use crate::metrics::{Counter, Gauge, Histogram};
use crate::power::DesignPower;
use crate::runtime::{DnnClient, Engine, OpQuery, VoltageSelectorClient};
use crate::vscale::{Mode, Optimizer, VoltageLut};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct ServingConfig {
    /// Benchmark / artifact variant (tabla, dnnweaver, ...).
    pub variant: String,
    /// Number of simulated FPGA instances (worker threads).
    pub n_instances: usize,
    /// DVFS epoch length (the simulator's τ, compressed for serving runs).
    pub epoch: Duration,
    /// Max requests queued before submit() applies backpressure.
    pub queue_capacity: usize,
    /// Max wait to fill a batch before dispatching a partial one.
    pub batch_timeout: Duration,
    /// Cycles one batch occupies an instance (service time = cycles / f).
    pub cycles_per_batch: f64,
    /// Voltage mode for the CC.
    pub mode: Mode,
    /// Use the AOT'd Pallas Voltage Selector through PJRT (true) or the
    /// native optimizer (false).
    pub selector_via_pjrt: bool,
    /// Nominal service capacity used to normalize the arrival counter.
    pub m_bins: usize,
    pub margin_t: f64,
    pub warmup_epochs: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            variant: "tabla".into(),
            n_instances: 2,
            epoch: Duration::from_millis(200),
            queue_capacity: 4096,
            batch_timeout: Duration::from_millis(5),
            cycles_per_batch: 2.0e5,
            mode: Mode::Proposed,
            selector_via_pjrt: true,
            m_bins: 10,
            margin_t: 0.05,
            warmup_epochs: 2,
        }
    }
}

/// One inference request.
pub struct Request {
    pub id: u64,
    pub payload: Vec<f32>,
    pub submitted: Instant,
}

/// Completed request record.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub worker: usize,
    pub latency: Duration,
    /// First output logit (proof of real compute).
    pub y0: f32,
}

/// Error returned when the queue is full (backpressure).
#[derive(Debug, PartialEq, Eq)]
pub struct QueueFull;

struct Shared {
    queue: Mutex<VecDeque<Request>>,
    notify: Condvar,
    shutdown: AtomicBool,
    /// Current freq ratio (f64 bits) published by the CC.
    freq_ratio: AtomicU64,
    vcore_mv: AtomicU64,
    vbram_mv: AtomicU64,
    arrivals_this_epoch: AtomicU64,
    pub completed: Counter,
    pub rejected: Counter,
    pub latency_us: Histogram,
    pub energy_j: Gauge,
    pub nominal_energy_j: Gauge,
}

impl Shared {
    fn freq_ratio(&self) -> f64 {
        f64::from_bits(self.freq_ratio.load(Ordering::Relaxed))
    }
}

/// Aggregate serving statistics.
#[derive(Clone, Debug)]
pub struct ServingStats {
    pub completed: u64,
    pub rejected: u64,
    pub mean_latency_s: f64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    pub energy_j: f64,
    pub nominal_energy_j: f64,
    pub power_gain: f64,
    pub epochs: usize,
    pub freq_ratio_now: f64,
    pub vcore_now: f64,
    pub vbram_now: f64,
}

/// Per-epoch CC trace row.
#[derive(Clone, Copy, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub load: f64,
    pub predicted: f64,
    pub freq_ratio: f64,
    pub vcore: f64,
    pub vbram: f64,
    pub power_w: f64,
}

pub struct Coordinator {
    pub cfg: ServingConfig,
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<Result<()>>>,
    controller: Option<std::thread::JoinHandle<Vec<EpochRecord>>>,
    next_id: AtomicU64,
    pub in_dim: usize,
    pub batch: usize,
}

impl Coordinator {
    /// Start workers + CC. `artifacts_dir` must contain `make artifacts`
    /// output; `design`/`optimizer` come from the platform build.
    pub fn start(
        cfg: ServingConfig,
        artifacts_dir: std::path::PathBuf,
        design: DesignPower,
        optimizer: Optimizer,
    ) -> Result<Self> {
        // Probe the artifact shape once (cheap engine, then dropped).
        let probe = Engine::open(&artifacts_dir)?;
        let client = DnnClient::new(&probe, &cfg.variant)?;
        let (in_dim, batch) = (client.in_dim, client.batch);
        drop(client);
        drop(probe);

        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            notify: Condvar::new(),
            shutdown: AtomicBool::new(false),
            freq_ratio: AtomicU64::new(1.0f64.to_bits()),
            vcore_mv: AtomicU64::new(800),
            vbram_mv: AtomicU64::new(950),
            arrivals_this_epoch: AtomicU64::new(0),
            completed: Counter::default(),
            rejected: Counter::default(),
            latency_us: Histogram::latency_us(),
            energy_j: Gauge::default(),
            nominal_energy_j: Gauge::default(),
        });

        // ---- workers --------------------------------------------------
        let mut workers = Vec::with_capacity(cfg.n_instances);
        for wid in 0..cfg.n_instances {
            let shared = shared.clone();
            let cfg2 = cfg.clone();
            let dir = artifacts_dir.clone();
            workers.push(std::thread::spawn(move || -> Result<()> {
                // Each instance owns its PJRT client (threads don't share
                // the engine, so no Sync bound is needed).
                let engine = Engine::open(&dir)?;
                let dnn = DnnClient::new(&engine, &cfg2.variant)?;
                let f_nom_hz = 1.0e6 * 100.0; // normalized; ratio matters
                loop {
                    // ---- batch formation ---------------------------------
                    let mut batch_reqs: Vec<Request> = Vec::with_capacity(dnn.batch);
                    {
                        let mut q = shared.queue.lock().unwrap();
                        loop {
                            while let Some(r) = q.pop_front() {
                                batch_reqs.push(r);
                                if batch_reqs.len() == dnn.batch {
                                    break;
                                }
                            }
                            if batch_reqs.len() == dnn.batch
                                || (!batch_reqs.is_empty())
                                || shared.shutdown.load(Ordering::Relaxed)
                            {
                                break;
                            }
                            let (qq, _timeout) = shared
                                .notify
                                .wait_timeout(q, cfg2.batch_timeout)
                                .unwrap();
                            q = qq;
                            if shared.shutdown.load(Ordering::Relaxed) && q.is_empty() {
                                break;
                            }
                        }
                    }
                    if batch_reqs.is_empty() {
                        if shared.shutdown.load(Ordering::Relaxed) {
                            return Ok(());
                        }
                        // Wait a little for work.
                        std::thread::sleep(cfg2.batch_timeout);
                        continue;
                    }
                    // Partial batches wait briefly for stragglers.
                    if batch_reqs.len() < dnn.batch {
                        let deadline = Instant::now() + cfg2.batch_timeout;
                        while batch_reqs.len() < dnn.batch && Instant::now() < deadline {
                            let mut q = shared.queue.lock().unwrap();
                            while let Some(r) = q.pop_front() {
                                batch_reqs.push(r);
                                if batch_reqs.len() == dnn.batch {
                                    break;
                                }
                            }
                            drop(q);
                            if batch_reqs.len() < dnn.batch {
                                std::thread::sleep(Duration::from_micros(200));
                            }
                        }
                    }

                    // ---- real inference ----------------------------------
                    let mut x = vec![0.0f32; dnn.batch * dnn.in_dim];
                    for (i, r) in batch_reqs.iter().enumerate() {
                        x[i * dnn.in_dim..(i + 1) * dnn.in_dim]
                            .copy_from_slice(&r.payload);
                    }
                    let y = dnn.infer(&x)?;

                    // ---- simulated FPGA occupancy ------------------------
                    let fr = shared.freq_ratio().max(0.05);
                    let service = cfg2.cycles_per_batch / (f_nom_hz * fr);
                    std::thread::sleep(Duration::from_secs_f64(service));

                    let now = Instant::now();
                    for (i, r) in batch_reqs.iter().enumerate() {
                        let lat = now.duration_since(r.submitted);
                        shared.latency_us.observe(lat.as_secs_f64() * 1e6);
                        shared.completed.inc();
                        let _ = Completion {
                            id: r.id,
                            worker: wid,
                            latency: lat,
                            y0: y[i * dnn.out_dim],
                        };
                    }
                }
            }));
        }

        // ---- central controller ----------------------------------------
        let controller = {
            let shared = shared.clone();
            let cfg2 = cfg.clone();
            let dir = artifacts_dir.clone();
            let design = design.clone();
            let optimizer = optimizer.clone();
            std::thread::spawn(move || -> Vec<EpochRecord> {
                let engine = if cfg2.selector_via_pjrt {
                    Engine::open(&dir).ok()
                } else {
                    None
                };
                let lut = VoltageLut::build(&optimizer, cfg2.m_bins, cfg2.margin_t, cfg2.mode);
                let mut predictor = MarkovPredictor::new(cfg2.m_bins, cfg2.warmup_epochs);
                // Nominal epoch capacity: all instances at f_nom.
                let f_nom_hz = 1.0e6 * 100.0;
                let cap = cfg2.n_instances as f64
                    * (f_nom_hz / cfg2.cycles_per_batch)
                    * 16.0 // artifact batch
                    * cfg2.epoch.as_secs_f64();
                let mut records = Vec::new();
                let mut epoch = 0usize;
                while !shared.shutdown.load(Ordering::Relaxed) {
                    std::thread::sleep(cfg2.epoch);
                    let arrivals =
                        shared.arrivals_this_epoch.swap(0, Ordering::Relaxed) as f64;
                    let load = (arrivals / cap).min(1.0);
                    predictor.observe(load);
                    let predicted = predictor.predict();

                    let entry = lut.entry_for_load(predicted);
                    let mut choice = entry.point;
                    // Ask the AOT'd Voltage Selector when enabled; fall
                    // back to the native point on any error.
                    if let Some(engine) = &engine {
                        let vs = VoltageSelectorClient::new(engine);
                        let sw = 1.0 / entry.freq_ratio;
                        let q = OpQuery {
                            alpha: optimizer.tables.op.alpha as f32,
                            beta: optimizer.tables.op.beta as f32,
                            gamma_l: optimizer.tables.op.gamma_l as f32,
                            gamma_m: optimizer.tables.op.gamma_m as f32,
                            sw: sw as f32,
                        };
                        if let Ok(choices) = vs.select(cfg2.mode, &optimizer.tables, &[q]) {
                            if let Some(c) = choices.first() {
                                choice.vcore = c.vcore;
                                choice.vbram = c.vbram;
                                choice.power_norm = c.power_norm;
                            }
                        }
                    }

                    shared
                        .freq_ratio
                        .store(entry.freq_ratio.to_bits(), Ordering::Relaxed);
                    shared
                        .vcore_mv
                        .store((choice.vcore * 1000.0) as u64, Ordering::Relaxed);
                    shared
                        .vbram_mv
                        .store((choice.vbram * 1000.0) as u64, Ordering::Relaxed);

                    // Energy integration at this epoch's operating point.
                    let f_mhz = design.spec.freq_mhz * entry.freq_ratio;
                    let p = design.breakdown(choice.vcore, choice.vbram, f_mhz).total_w()
                        * cfg2.n_instances as f64;
                    let p_nom = design.nominal().total_w() * cfg2.n_instances as f64;
                    shared.energy_j.add(p * cfg2.epoch.as_secs_f64());
                    shared
                        .nominal_energy_j
                        .add(p_nom * cfg2.epoch.as_secs_f64());
                    records.push(EpochRecord {
                        epoch,
                        load,
                        predicted,
                        freq_ratio: entry.freq_ratio,
                        vcore: choice.vcore,
                        vbram: choice.vbram,
                        power_w: p,
                    });
                    epoch += 1;
                }
                records
            })
        };

        Ok(Coordinator {
            cfg,
            shared,
            workers,
            controller: Some(controller),
            next_id: AtomicU64::new(0),
            in_dim,
            batch,
        })
    }

    /// Submit one request; `Err(QueueFull)` signals backpressure.
    pub fn submit(&self, payload: Vec<f32>) -> std::result::Result<u64, QueueFull> {
        assert_eq!(payload.len(), self.in_dim, "payload must be in_dim floats");
        let mut q = self.shared.queue.lock().unwrap();
        if q.len() >= self.cfg.queue_capacity {
            self.shared.rejected.inc();
            return Err(QueueFull);
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        q.push_back(Request { id, payload, submitted: Instant::now() });
        drop(q);
        self.shared.arrivals_this_epoch.fetch_add(1, Ordering::Relaxed);
        self.shared.notify.notify_one();
        Ok(id)
    }

    pub fn queue_len(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    pub fn stats(&self) -> ServingStats {
        let s = &self.shared;
        let energy = s.energy_j.get();
        let nominal = s.nominal_energy_j.get();
        ServingStats {
            completed: s.completed.get(),
            rejected: s.rejected.get(),
            mean_latency_s: s.latency_us.mean() / 1e6,
            p50_latency_s: s.latency_us.quantile(0.5) / 1e6,
            p99_latency_s: s.latency_us.quantile(0.99) / 1e6,
            energy_j: energy,
            nominal_energy_j: nominal,
            power_gain: if energy > 0.0 { nominal / energy } else { 1.0 },
            epochs: 0,
            freq_ratio_now: s.freq_ratio(),
            vcore_now: s.vcore_mv.load(Ordering::Relaxed) as f64 / 1000.0,
            vbram_now: s.vbram_mv.load(Ordering::Relaxed) as f64 / 1000.0,
        }
    }

    /// Stop accepting work, drain, join workers, and return the CC trace.
    pub fn shutdown(mut self) -> Result<(ServingStats, Vec<EpochRecord>)> {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        self.shared.notify.notify_all();
        for w in self.workers.drain(..) {
            w.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
        }
        let records = self
            .controller
            .take()
            .unwrap()
            .join()
            .map_err(|_| anyhow::anyhow!("controller panicked"))?;
        let mut stats = self.stats();
        stats.epochs = records.len();
        Ok((stats, records))
    }
}
