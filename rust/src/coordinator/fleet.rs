//! Multi-tenant sharded serving (DESIGN.md S11.5).
//!
//! Lifts `platform::fleet`'s *offline* group concept into the live request
//! path: one [`FleetServing`] coordinator serves several benchmark groups
//! (e.g. Tabla + DianNao) concurrently. Each group owns
//!
//! * its worker instances and their bounded [`ShardQueue`]s,
//! * a [`Dispatcher`] (least-loaded or round-robin) plus work stealing,
//! * its own Markov predictor, voltage LUT and published DVFS operating
//!   point (an independent DVFS domain),
//!
//! while a single Central Controller thread walks every group each epoch
//! (paper Fig. 9's CC, generalized to heterogeneous tenants) and a shared
//! fleet-level [`Registry`](crate::metrics::Registry) + [`FleetServingStats`]
//! aggregate power and QoS across groups — the live counterpart of
//! `platform::fleet::FleetReport`.
//!
//! Since the control-plane extraction (DESIGN.md S19) the CC itself is a
//! pure *plant*: it keeps the serving mechanics — arrival counters,
//! backlog/violation accounting, shard gating + drain, gauges, energy
//! integration — and delegates every per-epoch decision (predict,
//! guardband, margin ladder, elastic LUT lookup) to one
//! [`GroupController`](crate::control::GroupController) per group, the
//! same engine `platform::Platform` runs offline. The controllers' full
//! decision logs come back in
//! [`FleetServingReport::decision_records`]; replaying the observed
//! per-epoch loads through the offline platform must reproduce them
//! exactly (`tests/control_equivalence.rs`).
//!
//! Each group's CC decision is **elastic** (DESIGN.md S6.1): instead of
//! DVFS over a fixed instance count, the per-group
//! [`ElasticLut`](crate::vscale::ElasticLut) picks the minimum-power
//! (n_active, Vcore, Vbram, f) combination for the predicted bin. Gated
//! instances draw `pg_residual` of nominal power; their shards are
//! flagged so dispatch and stealing skip them, their workers park on the
//! shard condvar, and the CC drains any requests still queued on a gated
//! shard into the active shards every epoch — admitted work is never
//! dropped. `capacity_policy` selects the two baselines (`DvfsOnly`,
//! `GatingOnly`) for side-by-side runs.
//!
//! All sleeping, waiting and timestamping goes through the configured
//! [`Clock`](crate::clock::Clock) (DESIGN.md S18). Workers and the CC are
//! registered clock *actors* in deterministic order (workers first, then
//! the CC), so a fleet on a
//! [`VirtualClock`](crate::clock::VirtualClock) is a deterministic
//! discrete-event simulation: [`drive_scenario`] replays epochs in
//! virtual time and two runs with the same seed produce byte-identical
//! [`EpochRecord`] traces (`simtest`).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::clock::{self, ActorScope, Clock};

use super::backend::InferenceBackend;
use super::dispatch::{DispatchPolicy, Dispatcher};
use super::shard::ShardQueue;
use super::{Completion, EpochRecord, Request, SubmitError};
use crate::control::{
    ControlConfig, DecisionRecord, GroupController, LutSpec, Observation, QosTier,
};
use crate::markov::PredictorKind;
use crate::workload::FaultPlan;
use crate::metrics::{Counter, Gauge, Histogram, Registry};
use crate::platform::{build_platform, PlatformConfig, Policy};
use crate::power::DesignPower;
use crate::runtime::{Engine, OpQuery, VoltageSelectorClient};
use crate::vscale::{CapacityPolicy, Mode, Optimizer};

/// Normalized nominal service clock (Hz); only the ratio to the published
/// frequency matters for the simulated occupancy.
pub(crate) const F_NOM_HZ: f64 = 1.0e8;

/// What the CC thread hands back at shutdown: per-group epoch traces and
/// per-group control-plane decision logs, both index-aligned with the
/// fleet's groups.
type CcOutput = (Vec<Vec<EpochRecord>>, Vec<Vec<DecisionRecord>>);

/// One tenant group of a live fleet.
#[derive(Clone, Debug)]
pub struct GroupConfig {
    /// Benchmark / artifact variant served by this group.
    pub benchmark: String,
    /// Fraction of fleet traffic this group is provisioned for.
    pub share: f64,
    /// Worker instances (== shards) in this group.
    pub n_instances: usize,
    /// Per-tenant QoS tier (violation-rate target). Only refines an
    /// *enabled* run-level guardband: the effective target is
    /// [`QosTier::effective`]`(run_target, tier)`, so with the run-level
    /// `qos_target` at `None` (static margin) tiers are inert and the
    /// baselines stay bit-identical.
    pub qos_target: Option<f64>,
}

/// Configuration of a multi-tenant serving fleet.
#[derive(Clone, Debug)]
pub struct FleetServingConfig {
    /// Tenant groups; shares must sum to ~1.
    pub groups: Vec<GroupConfig>,
    /// DVFS epoch length (the simulator's τ, compressed for serving runs).
    pub epoch: Duration,
    /// Total queued requests a group may hold, split across its shards.
    pub queue_capacity: usize,
    /// Max wait for the first request of a batch before going idle-check.
    pub batch_timeout: Duration,
    /// Cycles one batch occupies an instance (service time = cycles / f).
    pub cycles_per_batch: f64,
    /// Voltage mode for every group's CC decisions.
    pub mode: Mode,
    /// Query the AOT'd Pallas Voltage Selector through PJRT when it is
    /// available (falls back to the native optimizer point otherwise).
    pub selector_via_pjrt: bool,
    /// Markov bins per group predictor.
    pub m_bins: usize,
    /// Throughput margin t for the voltage LUTs.
    pub margin_t: f64,
    /// Pure-training epochs before predictions are trusted.
    pub warmup_epochs: usize,
    /// Shard selection policy on the submit path.
    pub dispatch: DispatchPolicy,
    /// Allow idle workers to steal from sibling shards.
    pub steal: bool,
    /// How each group's CC trades instance gating against DVFS per epoch
    /// (DESIGN.md S6.1): `Hybrid` is the elastic capacity manager,
    /// `DvfsOnly` / `GatingOnly` are the baselines.
    pub capacity_policy: CapacityPolicy,
    /// Residual power fraction (of nominal) drawn by a gated instance.
    pub pg_residual: f64,
    /// Bounded backlog, in units of one epoch's nominal capacity — the
    /// live twin of the offline `PlatformConfig.max_backlog_steps` (the
    /// cross-path decision-equivalence contract requires the two to
    /// match; both default to 1.0).
    pub max_backlog_steps: f64,
    /// Workload predictor driving every group's CC (DESIGN.md S7):
    /// `Ensemble` runs all predictors shadow-mode per group and switches
    /// the active one with hysteresis.
    pub predictor: PredictorKind,
    /// Epochs per cycle assumed by the periodic predictor member.
    pub predictor_period: usize,
    /// `Some(target)` enables the adaptive QoS-feedback guardband
    /// (DESIGN.md S7.1): the margin shrinks while the observed per-tenant
    /// violation rate stays under `target` and boosts immediately on an
    /// under-prediction. `None` keeps the static `margin_t`.
    pub qos_target: Option<f64>,
    /// Deterministic fault-injection schedule (DESIGN.md S20): board
    /// failures gate + drain shards at CC epoch boundaries, straggler
    /// windows stretch worker service time, surge windows scale
    /// [`drive_scenario`]'s offered load. The default empty plan is
    /// bitwise-neutral — every query returns exactly `1.0` / no failure,
    /// so fault-free runs reproduce pre-fault traces byte-for-byte.
    pub faults: Arc<FaultPlan>,
    /// Time source for every wait/sleep/timestamp (DESIGN.md S18):
    /// `clock::wall()` for live serving, a
    /// [`VirtualClock`](crate::clock::VirtualClock) for deterministic
    /// simulation. Under a virtual clock the starting thread must already
    /// be a registered actor ([`ActorScope::enter`]).
    pub clock: Arc<dyn Clock>,
}

impl Default for FleetServingConfig {
    fn default() -> Self {
        FleetServingConfig {
            groups: vec![GroupConfig {
                benchmark: "tabla".into(),
                share: 1.0,
                n_instances: 2,
                qos_target: None,
            }],
            epoch: Duration::from_millis(200),
            queue_capacity: 4096,
            batch_timeout: Duration::from_millis(5),
            cycles_per_batch: 2.0e5,
            mode: Mode::Proposed,
            selector_via_pjrt: true,
            m_bins: 10,
            margin_t: 0.05,
            warmup_epochs: 2,
            dispatch: DispatchPolicy::LeastLoaded,
            steal: true,
            capacity_policy: CapacityPolicy::Hybrid,
            pg_residual: 0.02,
            max_backlog_steps: 1.0,
            predictor: PredictorKind::Markov,
            predictor_period: 96,
            qos_target: None,
            faults: Arc::new(FaultPlan::default()),
            clock: clock::wall(),
        }
    }
}

/// Shared state of one live group.
pub(super) struct GroupShared {
    pub(super) name: String,
    pub(super) share: f64,
    pub(super) n_instances: usize,
    pub(super) shards: Vec<Arc<ShardQueue>>,
    pub(super) dispatcher: Dispatcher,
    pub(super) backend_name: &'static str,
    pub(super) in_dim: usize,
    pub(super) out_dim: usize,
    pub(super) batch: usize,
    freq_ratio: AtomicU64,
    vcore_mv: AtomicU64,
    vbram_mv: AtomicU64,
    active_now: AtomicU64,
    /// Currently applied throughput margin (f64 bits).
    margin_now: AtomicU64,
    /// Index of the active prediction source in
    /// [`crate::markov::PREDICTOR_NAMES`].
    predictor_now: AtomicU64,
    arrivals_this_epoch: AtomicU64,
    /// Requests successfully placed on some shard. Shutdown-drain
    /// invariant: workers may exit only once
    /// `admitted == completed + failed` — queue emptiness alone is racy
    /// because the CC's gated-shard drain holds requests outside any
    /// queue while re-dispatching them.
    pub(super) admitted: Counter,
    pub(super) completed: Counter,
    pub(super) rejected: Counter,
    pub(super) failed: Counter,
    pub(super) stolen_batches: Counter,
    /// Requests the CC pulled off a gated or failed shard and re-queued
    /// onto the active set (failover re-dispatch; never a drop).
    pub(super) redispatched: Counter,
    /// Boards of this group currently failed by the fault plan.
    failed_boards: AtomicU64,
    pub(super) violations: Counter,
    pub(super) epochs: Counter,
    pub(super) latency_us: Histogram,
    pub(super) energy_j: Gauge,
    pub(super) nominal_energy_j: Gauge,
}

impl GroupShared {
    fn freq_ratio(&self) -> f64 {
        f64::from_bits(self.freq_ratio.load(Ordering::Relaxed))
    }
}

/// Round a rail voltage to integer millivolts for the published gauges.
/// Truncation would report e.g. 0.7 V (stored as 0.6999…) as 699 mV.
pub(crate) fn volts_to_mv(v: f64) -> u64 {
    (v * 1000.0).round() as u64
}

/// Pull a batch for worker `wid`: first from its home shard (waiting up to
/// `wait` for the first request), then — when idle and `steal` is on —
/// from the deepest sibling shard. Gated siblings are skipped (their
/// backlog belongs to the CC's drain/re-dispatch pass). Returns the batch
/// and whether it was stolen.
pub(super) fn claim_batch(
    shards: &[Arc<ShardQueue>],
    wid: usize,
    max: usize,
    wait: Duration,
    steal: bool,
) -> (Vec<Request>, bool) {
    let batch = shards[wid].pop_wait(max, wait);
    if !batch.is_empty() || !steal || shards.len() < 2 {
        return (batch, false);
    }
    // Steal roughly half of the deepest sibling's backlog.
    let mut victim = None;
    let mut depth = 0usize;
    for (i, s) in shards.iter().enumerate() {
        if i != wid && !s.is_gated() && s.len() > depth {
            depth = s.len();
            victim = Some(i);
        }
    }
    match victim {
        Some(v) => {
            let take = depth.div_ceil(2).clamp(1, max);
            let stolen = shards[v].steal_upto(take);
            let got = !stolen.is_empty();
            (stolen, got)
        }
        None => (Vec::new(), false),
    }
}

/// Per-group serving statistics (live or final).
#[derive(Clone, Debug)]
pub struct GroupServingStats {
    /// Group / benchmark name.
    pub name: String,
    /// Provisioned traffic share.
    pub share: f64,
    /// Worker instances in the group.
    pub n_instances: usize,
    /// Inference backend the group's workers use (`pjrt` or `native`).
    pub backend: &'static str,
    /// Requests accepted onto some shard (the drain invariant:
    /// `admitted == completed + failed` at shutdown).
    pub admitted: u64,
    /// Requests served to completion.
    pub completed: u64,
    /// Requests refused by backpressure.
    pub rejected: u64,
    /// Requests dropped because the inference backend errored.
    pub failed: u64,
    /// Batches obtained by work stealing.
    pub stolen_batches: u64,
    /// Requests re-dispatched off gated/failed shards by the CC drain.
    pub redispatched: u64,
    /// Boards currently failed by the fault plan.
    pub failed_boards_now: usize,
    /// Mean end-to-end latency (s).
    pub mean_latency_s: f64,
    /// Median end-to-end latency (s).
    pub p50_latency_s: f64,
    /// 99th-percentile end-to-end latency (s).
    pub p99_latency_s: f64,
    /// Energy integrated at the CC's operating points (J).
    pub energy_j: f64,
    /// Energy the group would have drawn at nominal V/f (J).
    pub nominal_energy_j: f64,
    /// Paper's headline metric: nominal energy / actual energy.
    pub power_gain: f64,
    /// Fraction of epochs whose demand exceeded served capacity.
    pub violation_rate: f64,
    /// DVFS epochs elapsed.
    pub epochs: u64,
    /// Currently published f / f_nom.
    pub freq_ratio_now: f64,
    /// Currently published core-rail voltage (V).
    pub vcore_now: f64,
    /// Currently published BRAM-rail voltage (V).
    pub vbram_now: f64,
    /// Instances currently active (not gated by the elastic manager).
    pub active_now: usize,
    /// Throughput margin the CC currently applies (static `margin_t` or
    /// the adaptive guardband's ladder level).
    pub margin_now: f64,
    /// Prediction source currently active (the ensemble reports its
    /// member).
    pub predictor_now: &'static str,
    /// Requests currently queued across the group's shards.
    pub queue_depth: usize,
}

/// Fleet-level aggregate over all groups.
#[derive(Clone, Debug)]
pub struct FleetServingStats {
    /// Per-group breakdown.
    pub per_group: Vec<GroupServingStats>,
    /// Total completed requests.
    pub completed: u64,
    /// Total rejected requests.
    pub rejected: u64,
    /// Total backend-failed requests.
    pub failed: u64,
    /// Total stolen batches.
    pub stolen_batches: u64,
    /// Total failover re-dispatches.
    pub redispatched: u64,
    /// Total integrated energy (J).
    pub energy_j: f64,
    /// Total nominal-baseline energy (J).
    pub nominal_energy_j: f64,
    /// Fleet power gain (nominal energy / actual energy).
    pub power_gain: f64,
    /// Worst per-group violation rate (QoS is per-tenant).
    pub violation_rate: f64,
    /// DVFS epochs elapsed (max over groups).
    pub epochs: u64,
}

/// Final outcome of a fleet serving run.
#[derive(Clone, Debug)]
pub struct FleetServingReport {
    /// Aggregate + per-group statistics at shutdown.
    pub stats: FleetServingStats,
    /// Per-group CC epoch traces (index-aligned with `stats.per_group`).
    pub epoch_records: Vec<Vec<EpochRecord>>,
    /// Per-group control-plane decision logs (index-aligned with
    /// `stats.per_group`): the exact [`DecisionRecord`] sequence each
    /// group's [`GroupController`] produced, one per epoch. Replaying
    /// the observed epoch loads through the offline `platform::Platform`
    /// must reproduce these sequences identically
    /// (`tests/control_equivalence.rs`).
    pub decision_records: Vec<Vec<DecisionRecord>>,
}

/// The live multi-tenant coordinator.
pub struct FleetServing {
    /// Configuration the fleet was started with.
    pub cfg: FleetServingConfig,
    groups: Vec<Arc<GroupShared>>,
    registry: Arc<Registry>,
    shutdown: Arc<AtomicBool>,
    workers: Vec<std::thread::JoinHandle<()>>,
    controller: Option<std::thread::JoinHandle<CcOutput>>,
    rejected_total: Arc<Counter>,
    next_id: AtomicU64,
}

impl FleetServing {
    /// Start a fleet, building each group's power model and optimizer from
    /// its benchmark name (`platform::build_platform`).
    pub fn start(cfg: FleetServingConfig, artifacts_dir: std::path::PathBuf) -> Result<Self> {
        let mut built = Vec::with_capacity(cfg.groups.len());
        for g in &cfg.groups {
            let platform = build_platform(
                &g.benchmark,
                PlatformConfig::default(),
                Policy::Dvfs(cfg.mode),
            )
            .map_err(anyhow::Error::msg)?;
            built.push((platform.design.clone(), platform.optimizer_ref().clone()));
        }
        Self::start_with(cfg, artifacts_dir, built)
    }

    /// Start a fleet with pre-built `(design, optimizer)` pairs, one per
    /// group (index-aligned with `cfg.groups`).
    pub fn start_with(
        cfg: FleetServingConfig,
        artifacts_dir: std::path::PathBuf,
        built: Vec<(DesignPower, Optimizer)>,
    ) -> Result<Self> {
        anyhow::ensure!(!cfg.groups.is_empty(), "fleet needs at least one group");
        anyhow::ensure!(
            built.len() == cfg.groups.len(),
            "got {} design/optimizer pairs for {} groups",
            built.len(),
            cfg.groups.len()
        );
        let share_sum: f64 = cfg.groups.iter().map(|g| g.share).sum();
        anyhow::ensure!(
            (share_sum - 1.0).abs() < 1e-6,
            "group shares sum to {share_sum}, expected 1"
        );
        for g in &cfg.groups {
            anyhow::ensure!(g.share > 0.0, "{}: share must be positive", g.benchmark);
            anyhow::ensure!(g.n_instances >= 1, "{}: need >= 1 instance", g.benchmark);
            if let Some(t) = g.qos_target {
                anyhow::ensure!(
                    (0.0..1.0).contains(&t),
                    "{}: qos tier target {t} outside [0, 1)",
                    g.benchmark
                );
            }
        }
        // Structural plan checks (windows non-empty, slowdowns >= 1, ...)
        // are layout-independent; index bounds are checked against each
        // group's own instance count since groups may differ in size.
        cfg.faults
            .validate(usize::MAX, usize::MAX)
            .map_err(anyhow::Error::msg)?;
        for f in &cfg.faults.board_failures {
            anyhow::ensure!(
                f.group < cfg.groups.len() && f.shard < cfg.groups[f.group].n_instances,
                "fault plan: board failure ({}, {}) outside the fleet layout",
                f.group,
                f.shard
            );
        }
        for w in &cfg.faults.stragglers {
            anyhow::ensure!(
                w.group < cfg.groups.len() && w.shard < cfg.groups[w.group].n_instances,
                "fault plan: straggler ({}, {}) outside the fleet layout",
                w.group,
                w.shard
            );
        }
        // Deterministic virtual-time scheduling needs every participating
        // thread registered; catching a forgotten driver here beats a
        // silent free-running simulation.
        anyhow::ensure!(
            cfg.clock.current_is_actor(),
            "VirtualClock: register the starting thread as an actor first \
             (clock::ActorScope::enter) so the simulation stays deterministic"
        );

        let registry = Arc::new(Registry::new());
        let shutdown = Arc::new(AtomicBool::new(false));

        // ---- per-group shared state -----------------------------------
        let mut groups: Vec<Arc<GroupShared>> = Vec::with_capacity(cfg.groups.len());
        for g in &cfg.groups {
            // Probe once for dims + backend availability; workers re-open
            // their own backend (PJRT clients are not shared across
            // threads).
            let probe = InferenceBackend::open(&artifacts_dir, &g.benchmark);
            let per_shard = cfg.queue_capacity.div_ceil(g.n_instances);
            groups.push(Arc::new(GroupShared {
                name: g.benchmark.clone(),
                share: g.share,
                n_instances: g.n_instances,
                shards: (0..g.n_instances)
                    .map(|_| Arc::new(ShardQueue::with_clock(per_shard, cfg.clock.clone())))
                    .collect(),
                dispatcher: Dispatcher::new(cfg.dispatch),
                backend_name: probe.name(),
                in_dim: probe.in_dim(),
                out_dim: probe.out_dim(),
                batch: probe.batch(),
                freq_ratio: AtomicU64::new(1.0f64.to_bits()),
                vcore_mv: AtomicU64::new(800),
                vbram_mv: AtomicU64::new(950),
                active_now: AtomicU64::new(g.n_instances as u64),
                margin_now: AtomicU64::new(cfg.margin_t.to_bits()),
                // Seed with the *active member* name so stats queried
                // before the first CC epoch report a real predictor
                // ("markov"), never the literal "ensemble" — the offline
                // path's active_name() semantics.
                predictor_now: AtomicU64::new(PredictorKind::index_of_name(
                    cfg.predictor.initial_active_name(),
                ) as u64),
                arrivals_this_epoch: AtomicU64::new(0),
                admitted: Counter::default(),
                completed: Counter::default(),
                rejected: Counter::default(),
                failed: Counter::default(),
                stolen_batches: Counter::default(),
                redispatched: Counter::default(),
                failed_boards: AtomicU64::new(0),
                violations: Counter::default(),
                epochs: Counter::default(),
                latency_us: Histogram::latency_us(),
                energy_j: Gauge::default(),
                nominal_energy_j: Gauge::default(),
            }));
        }

        // ---- workers ---------------------------------------------------
        // Clock actors are registered *here*, on the starting thread, so
        // their ids — and with them every virtual-time scheduling decision
        // — are assigned in deterministic program order (workers in
        // group/instance order, then the CC), not in racy thread-startup
        // order.
        let mut workers = Vec::new();
        for (gi, gshared) in groups.iter().enumerate() {
            for wid in 0..cfg.groups[gi].n_instances {
                let g = gshared.clone();
                let dir = artifacts_dir.clone();
                let stop = shutdown.clone();
                let fleet_completed = registry.counter("fleet.completed");
                let cycles = cfg.cycles_per_batch;
                let batch_timeout = cfg.batch_timeout;
                let steal = cfg.steal;
                let faults = cfg.faults.clone();
                let epoch_len = cfg.epoch;
                let clock = cfg.clock.clone();
                let actor = clock.register_actor(&format!("{}:w{wid}", g.name));
                workers.push(std::thread::spawn(move || {
                    let _actor = ActorScope::attach(&clock, actor);
                    let backend = InferenceBackend::open(&dir, &g.name);
                    let batch_cap = backend.batch();
                    let in_dim = backend.in_dim();
                    loop {
                        // Gated instance: park on the shard condvar until
                        // the CC scales back up or shutdown starts. The
                        // timeout bounds a racily-missed wakeup.
                        if g.shards[wid].is_gated() && !stop.load(Ordering::Relaxed) {
                            g.shards[wid].park_while_gated(Duration::from_millis(25));
                            continue;
                        }
                        let (mut reqs, stolen) =
                            claim_batch(&g.shards, wid, batch_cap, batch_timeout, steal);
                        if stolen {
                            g.stolen_batches.inc();
                        }
                        if reqs.is_empty() {
                            // Exit only once every admitted request has
                            // been served or failed. After `stop` no new
                            // requests are admitted (shutdown consumes
                            // the fleet), so `admitted` is frozen and
                            // this equality is race-free — unlike a
                            // queue-emptiness check, it also covers
                            // requests the CC's gated-shard drain is
                            // holding outside any queue. The Acquire on
                            // the stop flag pairs with shutdown()'s
                            // Release store so every admitted.inc()
                            // sequenced before shutdown is visible here;
                            // stale (low) completed/failed reads only
                            // delay exit by a loop iteration.
                            if stop.load(Ordering::Acquire)
                                && g.admitted.get()
                                    == g.completed.get() + g.failed.get()
                            {
                                return;
                            }
                            continue;
                        }
                        // Top up a partial batch without waiting.
                        if reqs.len() < batch_cap {
                            reqs.extend(g.shards[wid].pop_upto(batch_cap - reqs.len()));
                        }

                        // ---- real inference (PJRT or native) -----------
                        let mut x = vec![0.0f32; batch_cap * in_dim];
                        for (i, r) in reqs.iter().enumerate() {
                            x[i * in_dim..(i + 1) * in_dim].copy_from_slice(&r.payload);
                        }
                        // A failing backend must not kill the worker: a dead
                        // worker leaves its shard undrained and shutdown()
                        // would wait on it forever. Count and move on.
                        let y = match backend.infer(&x) {
                            Ok(y) => y,
                            Err(_) => {
                                g.failed.add(reqs.len() as u64);
                                continue;
                            }
                        };

                        // ---- simulated FPGA occupancy ------------------
                        // A straggler window stretches this shard's
                        // service time by the plan's slowdown; outside a
                        // window (and on the empty plan) the factor is
                        // exactly 1.0, so the multiply is bitwise-neutral.
                        let fr = g.freq_ratio().max(0.05);
                        let slow = faults.straggler_slowdown(
                            gi,
                            wid,
                            clock::epoch_index(clock.now(), epoch_len),
                        );
                        let service = cycles / (F_NOM_HZ * fr) * slow;
                        clock.sleep(Duration::from_secs_f64(service));

                        let now = clock.now();
                        for (i, r) in reqs.iter().enumerate() {
                            let lat_ticks = now.saturating_sub(r.submitted);
                            g.latency_us.observe(lat_ticks as f64 / 1e3);
                            g.completed.inc();
                            fleet_completed.inc();
                            let _ = Completion {
                                id: r.id,
                                worker: wid,
                                latency: clock::to_duration(lat_ticks),
                                y0: y[i * backend.out_dim()],
                            };
                        }
                    }
                }));
            }
        }

        // ---- central controller (one thread for the whole fleet) -------
        let controller = {
            let groups = groups.clone();
            let cfg2 = cfg.clone();
            let dir = artifacts_dir.clone();
            let stop = shutdown.clone();
            let registry2 = registry.clone();
            let cc_actor = cfg.clock.register_actor("cc");
            std::thread::spawn(move || -> CcOutput {
                let _actor = ActorScope::attach(&cfg2.clock, cc_actor);
                let engine = if cfg2.selector_via_pjrt {
                    Engine::open(&dir).ok()
                } else {
                    None
                };
                struct GroupCc {
                    design: DesignPower,
                    optimizer: Optimizer,
                    /// The shared per-group control plane (DESIGN.md
                    /// S19): predictor, guardband, margin ladder and
                    /// per-level elastic LUTs — the same engine the
                    /// offline platform runs.
                    controller: GroupController,
                    backlog: f64,
                    cap: f64,
                    margin_gauge: std::sync::Arc<Gauge>,
                    predictor_gauge: std::sync::Arc<Gauge>,
                    // Operating point that served the epoch now ending
                    // (published at the END of the previous iteration).
                    served_fr: f64,
                    served_vcore: f64,
                    served_vbram: f64,
                    served_active: usize,
                    /// Shards that actually served (the decision's active
                    /// count minus fault-plan failures). Equals
                    /// `served_active` whenever no board is failed, so
                    /// fault-free capacity and energy are bit-identical
                    /// to the pre-fault plant.
                    served_healthy: usize,
                    /// Boards failed while the epoch was served.
                    served_failed: usize,
                    /// Straggler capacity factor of the serving set
                    /// (exactly 1.0 without straggler windows).
                    served_slow: f64,
                }
                let mut ccs: Vec<GroupCc> = built
                    .into_iter()
                    .zip(&groups)
                    .enumerate()
                    .map(|(gi, ((design, optimizer), g))| {
                        // All decision machinery — margin ladder, LUT
                        // builds, guardband — is the controller's
                        // (DESIGN.md S19); the CC only picks the elastic
                        // LUT family matching its capacity policy.
                        let controller = GroupController::new(
                            ControlConfig {
                                m_bins: cfg2.m_bins,
                                margin_t: cfg2.margin_t,
                                warmup: cfg2.warmup_epochs,
                                predictor: cfg2.predictor,
                                predictor_period: cfg2.predictor_period,
                                // Tenant tiers refine only an *enabled*
                                // run-level guardband (DESIGN.md S20);
                                // qos_target None keeps every baseline
                                // bit-identical regardless of tier.
                                qos_target: QosTier::effective(
                                    cfg2.qos_target,
                                    cfg2.groups[gi].qos_target,
                                ),
                            },
                            &optimizer,
                            LutSpec::Elastic {
                                mode: cfg2.mode,
                                n_instances: g.n_instances,
                                residual: cfg2.pg_residual,
                                policy: cfg2.capacity_policy,
                                latency_cap_sw: f64::INFINITY,
                            },
                        );
                        let cap = g.n_instances as f64
                            * (F_NOM_HZ / cfg2.cycles_per_batch)
                            * g.batch as f64
                            * cfg2.epoch.as_secs_f64();
                        let served_vcore = design.chars.logic.v_nom;
                        let served_vbram = design.chars.bram.v_nom;
                        let margin_gauge =
                            registry2.gauge(&format!("{}.margin_now", g.name));
                        let predictor_gauge =
                            registry2.gauge(&format!("{}.predictor_now", g.name));
                        // Seed the gauges so reads before the first epoch
                        // see the startup state (static margin, active
                        // predictor member) instead of zeros.
                        margin_gauge.set(cfg2.margin_t);
                        predictor_gauge.set(PredictorKind::index_of_name(
                            controller.predictor_now(),
                        ) as f64);
                        GroupCc {
                            design,
                            optimizer,
                            controller,
                            backlog: 0.0,
                            cap,
                            margin_gauge,
                            predictor_gauge,
                            served_fr: 1.0,
                            served_vcore,
                            served_vbram,
                            served_active: g.n_instances,
                            served_healthy: g.n_instances,
                            served_failed: 0,
                            // Epoch 0 is served before any CC pass, so
                            // no board is gated yet; straggler windows
                            // may still cover it.
                            served_slow: {
                                let all: Vec<usize> = (0..g.n_instances).collect();
                                cfg2.faults.capacity_factor(gi, &all, 0)
                            },
                        }
                    })
                    .collect();
                let mut records: Vec<Vec<EpochRecord>> =
                    vec![Vec::new(); groups.len()];
                let mut epoch = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    cfg2.clock.sleep(cfg2.epoch);
                    for (gi, g) in groups.iter().enumerate() {
                        let cc = &mut ccs[gi];
                        let arrivals =
                            g.arrivals_this_epoch.swap(0, Ordering::Relaxed) as f64;
                        let load = (arrivals / cc.cap).min(1.0);

                        // ---- per-tenant QoS accounting ------------------
                        // Demand is judged against the capacity that
                        // actually served this epoch — active instances ×
                        // their frequency — not the one about to be
                        // published. (Same expression shape as the
                        // offline plant's capacity so the two paths'
                        // float results are bit-identical.)
                        // Failures shrink the serving set (`served_healthy
                        // <= served_active`) and straggler windows scale
                        // it by the mean service-rate factor; both are
                        // exactly neutral on an empty fault plan.
                        let served_cap = cc.served_fr
                            * (cc.served_healthy as f64 / g.n_instances as f64)
                            * cc.served_slow;
                        let demand = load + cc.backlog;
                        let delivered = demand.min(served_cap);
                        cc.backlog =
                            (demand - delivered).min(cfg2.max_backlog_steps);
                        let violated = demand - delivered > 1e-9;
                        if violated {
                            g.violations.inc();
                        }

                        // ---- one decision via the shared control plane --
                        // Misprediction judgement, predictor training,
                        // guardband feedback, margin-ladder quantization,
                        // backlog backpressure and the elastic LUT lookup
                        // all live in control::GroupController (DESIGN.md
                        // S19) — the exact engine the offline platform
                        // runs per step.
                        let d = cc.controller.decide(&Observation {
                            load,
                            qos_violation: violated,
                            backlog: cc.backlog,
                        });

                        // Refine through the AOT'd Voltage Selector when
                        // available; keep the native point on any error.
                        // PG-only pins active instances at nominal V/f, so
                        // its point is never refined. (Serving-side
                        // refinement, not a control decision: virtual-time
                        // runs skip it so the decision log stays
                        // environment-independent.)
                        let (mut vcore_next, mut vbram_next) = (d.vcore, d.vbram);
                        if cfg2.capacity_policy != CapacityPolicy::GatingOnly {
                            if let Some(engine) = &engine {
                                let vs = VoltageSelectorClient::new(engine);
                                let q = OpQuery {
                                    alpha: cc.optimizer.tables.op.alpha as f32,
                                    beta: cc.optimizer.tables.op.beta as f32,
                                    gamma_l: cc.optimizer.tables.op.gamma_l as f32,
                                    gamma_m: cc.optimizer.tables.op.gamma_m as f32,
                                    sw: (1.0 / d.freq_ratio) as f32,
                                };
                                if let Ok(choices) =
                                    vs.select(cfg2.mode, &cc.optimizer.tables, &[q])
                                {
                                    if let Some(c) = choices.first() {
                                        vcore_next = c.vcore;
                                        vbram_next = c.vbram;
                                    }
                                }
                            }
                        }

                        // ---- energy integration + trace row -------------
                        // Charged at the point that served the epoch; the
                        // freshly chosen point is charged next epoch.
                        // Active instances at the scaled point, gated ones
                        // at the residual of nominal.
                        let f_mhz = cc.design.spec.freq_mhz * cc.served_fr;
                        let p_board = cc
                            .design
                            .breakdown(cc.served_vcore, cc.served_vbram, f_mhz)
                            .total_w();
                        let board_nom = cc.design.nominal().total_w();
                        // Failed boards are powered down like gated ones
                        // (residual draw), so energy charges the healthy
                        // serving set only.
                        let gated =
                            (g.n_instances - cc.served_healthy) as f64;
                        let p = p_board * cc.served_healthy as f64
                            + board_nom * cfg2.pg_residual * gated;
                        let p_nom = board_nom * g.n_instances as f64;
                        g.energy_j.add(p * cfg2.epoch.as_secs_f64());
                        g.nominal_energy_j.add(p_nom * cfg2.epoch.as_secs_f64());
                        g.epochs.inc();
                        // Same column alignment as the offline
                        // StepRecord: the operating point that SERVED
                        // this epoch, plus the forecast/margin/predictor
                        // of the decision MADE this epoch.
                        records[gi].push(EpochRecord {
                            epoch,
                            load,
                            decision: DecisionRecord {
                                predicted: d.predicted,
                                freq_ratio: cc.served_fr,
                                vcore: cc.served_vcore,
                                vbram: cc.served_vbram,
                                n_active: cc.served_active,
                                predictor: d.predictor,
                                margin: d.margin,
                            },
                            power_w: p,
                            n_failed: cc.served_failed,
                            slow_factor: cc.served_slow,
                        });

                        // ---- publish the next operating point -----------
                        g.freq_ratio
                            .store(d.freq_ratio.to_bits(), Ordering::Relaxed);
                        g.vcore_mv
                            .store(volts_to_mv(vcore_next), Ordering::Relaxed);
                        g.vbram_mv
                            .store(volts_to_mv(vbram_next), Ordering::Relaxed);
                        g.active_now
                            .store(d.n_active as u64, Ordering::Relaxed);
                        g.margin_now
                            .store(d.margin.to_bits(), Ordering::Relaxed);
                        g.predictor_now.store(
                            PredictorKind::index_of_name(d.predictor) as u64,
                            Ordering::Relaxed,
                        );
                        cc.margin_gauge.set(d.margin);
                        cc.predictor_gauge
                            .set(PredictorKind::index_of_name(d.predictor) as f64);

                        // ---- gate / ungate + drain ----------------------
                        // The serving set for the next epoch is the first
                        // `n_active` *non-failed* shards (DESIGN.md S20).
                        // Without failures that is exactly [0, n_active),
                        // the pre-fault behavior. Everything outside the
                        // set — gated by the decision OR downed by the
                        // plan — is drained and re-dispatched into it so
                        // admitted requests are never dropped.
                        let next_epoch = epoch + 1;
                        let failed_mask: Vec<bool> = (0..g.n_instances)
                            .map(|i| cfg2.faults.board_failed(gi, i, next_epoch))
                            .collect();
                        let n_failed =
                            failed_mask.iter().filter(|&&f| f).count();
                        let mut active: Vec<usize> =
                            Vec::with_capacity(d.n_active);
                        for i in 0..g.n_instances {
                            if !failed_mask[i] && active.len() < d.n_active {
                                active.push(i);
                            }
                        }
                        if active.is_empty() {
                            // A plan downing every board at once would
                            // strand admitted work and deadlock the
                            // shutdown drain invariant; serve the
                            // decision's set as if the last board refused
                            // to die.
                            active.extend(0..d.n_active.clamp(1, g.n_instances));
                        }
                        for (i, s) in g.shards.iter().enumerate() {
                            s.set_failed(failed_mask[i]);
                            s.set_gated(!active.contains(&i));
                        }
                        let mut cursor = 0usize;
                        for (si, shard) in g.shards.iter().enumerate() {
                            if active.contains(&si) {
                                continue;
                            }
                            for mut r in shard.drain_all() {
                                let mut placed = false;
                                for _ in 0..active.len() {
                                    let t = active[cursor % active.len()];
                                    cursor += 1;
                                    match g.shards[t].try_push(r) {
                                        Ok(()) => {
                                            placed = true;
                                            break;
                                        }
                                        Err(back) => r = back,
                                    }
                                }
                                if placed {
                                    g.redispatched.inc();
                                } else {
                                    // Every active shard is full: return
                                    // the request to its original shard
                                    // (bound-free) and retry next epoch —
                                    // never drop admitted work.
                                    shard.push_unbounded(r);
                                }
                            }
                        }
                        g.failed_boards
                            .store(n_failed as u64, Ordering::Relaxed);
                        cc.served_fr = d.freq_ratio;
                        cc.served_vcore = vcore_next;
                        cc.served_vbram = vbram_next;
                        cc.served_active = d.n_active;
                        cc.served_healthy = active.len();
                        cc.served_failed = n_failed;
                        cc.served_slow =
                            cfg2.faults.capacity_factor(gi, &active, next_epoch);
                    }
                    epoch += 1;
                }
                let decisions = ccs
                    .iter_mut()
                    .map(|cc| cc.controller.take_decisions())
                    .collect();
                (records, decisions)
            })
        };

        let rejected_total = registry.counter("fleet.rejected");
        Ok(FleetServing {
            cfg,
            groups,
            registry,
            shutdown,
            workers,
            controller: Some(controller),
            rejected_total,
            next_id: AtomicU64::new(0),
        })
    }

    /// Number of tenant groups.
    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// Benchmark names of the groups, in index order.
    pub fn group_names(&self) -> Vec<String> {
        self.groups.iter().map(|g| g.name.clone()).collect()
    }

    /// Index of the group serving `benchmark`, if any.
    pub fn group_index(&self, benchmark: &str) -> Option<usize> {
        self.groups.iter().position(|g| g.name == benchmark)
    }

    /// Input feature width of a group's model.
    ///
    /// # Panics
    /// Like slice indexing, panics when `group >= n_groups()`; resolve
    /// indices with [`FleetServing::group_index`] first. The *request*
    /// path ([`FleetServing::submit`]) never panics — it returns
    /// [`SubmitError::UnknownGroup`] instead.
    pub fn in_dim(&self, group: usize) -> usize {
        self.groups[group].in_dim
    }

    /// Artifact batch size of a group's model.
    ///
    /// # Panics
    /// Panics when `group >= n_groups()` (see [`FleetServing::in_dim`]).
    pub fn batch(&self, group: usize) -> usize {
        self.groups[group].batch
    }

    /// Requests currently queued across a group's shards.
    ///
    /// # Panics
    /// Panics when `group >= n_groups()` (see [`FleetServing::in_dim`]).
    pub fn queue_len(&self, group: usize) -> usize {
        self.groups[group].shards.iter().map(|s| s.len()).sum()
    }

    /// The shared fleet-level metrics registry.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// The fleet's time source (wall or virtual); [`drive_scenario`] paces
    /// epochs on it so scenario replay follows the fleet's notion of time.
    pub fn clock(&self) -> &Arc<dyn Clock> {
        &self.cfg.clock
    }

    /// Submit one request to a group. Errors are typed backpressure-style
    /// signals, never aborts: `UnknownGroup` for an out-of-range index,
    /// `BadPayload` for a wrong-width payload, `QueueFull` when every
    /// active shard of the group is at capacity.
    pub fn submit(
        &self,
        group: usize,
        payload: Vec<f32>,
    ) -> std::result::Result<u64, SubmitError> {
        let g = self
            .groups
            .get(group)
            .ok_or_else(|| SubmitError::UnknownGroup(format!("group index {group}")))?;
        if payload.len() != g.in_dim {
            return Err(SubmitError::BadPayload { expected: g.in_dim, got: payload.len() });
        }
        // The CC's workload counter sees *offered* demand (paper Fig. 9's
        // arrival counter), so rejected requests still push the predictor
        // toward higher frequency — essential under flash-crowd overload,
        // where admitted traffic alone is capped by the current drain rate.
        g.arrivals_this_epoch.fetch_add(1, Ordering::Relaxed);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = Request { id, payload, submitted: self.cfg.clock.now() };
        let first = g.dispatcher.pick(&g.shards);
        match g.shards[first].try_push(req) {
            Ok(()) => {}
            Err(back) => {
                req = back;
                let n = g.shards.len();
                let mut placed = false;
                for step in 1..n {
                    let idx = (first + step) % n;
                    // Gated shards' workers are parked; routing there
                    // would strand the request until the next CC drain.
                    if g.shards[idx].is_gated() {
                        continue;
                    }
                    match g.shards[idx].try_push(req) {
                        Ok(()) => {
                            placed = true;
                            break;
                        }
                        Err(back) => req = back,
                    }
                }
                if !placed {
                    g.rejected.inc();
                    self.rejected_total.inc();
                    return Err(SubmitError::QueueFull);
                }
            }
        }
        g.admitted.inc();
        Ok(id)
    }

    /// Submit by benchmark name (convenience over [`FleetServing::submit`]);
    /// an unknown name returns `Err(SubmitError::UnknownGroup)`.
    pub fn submit_to(
        &self,
        benchmark: &str,
        payload: Vec<f32>,
    ) -> std::result::Result<u64, SubmitError> {
        let gi = self
            .group_index(benchmark)
            .ok_or_else(|| SubmitError::UnknownGroup(benchmark.to_string()))?;
        self.submit(gi, payload)
    }

    fn group_stats(&self, g: &GroupShared) -> GroupServingStats {
        let energy = g.energy_j.get();
        let nominal = g.nominal_energy_j.get();
        let epochs = g.epochs.get();
        GroupServingStats {
            name: g.name.clone(),
            share: g.share,
            n_instances: g.n_instances,
            backend: g.backend_name,
            admitted: g.admitted.get(),
            completed: g.completed.get(),
            rejected: g.rejected.get(),
            failed: g.failed.get(),
            stolen_batches: g.stolen_batches.get(),
            redispatched: g.redispatched.get(),
            failed_boards_now: g.failed_boards.load(Ordering::Relaxed) as usize,
            mean_latency_s: g.latency_us.mean() / 1e6,
            p50_latency_s: g.latency_us.quantile(0.5) / 1e6,
            p99_latency_s: g.latency_us.quantile(0.99) / 1e6,
            energy_j: energy,
            nominal_energy_j: nominal,
            power_gain: if energy > 0.0 { nominal / energy } else { 1.0 },
            violation_rate: g.violations.get() as f64 / epochs.max(1) as f64,
            epochs,
            freq_ratio_now: g.freq_ratio(),
            vcore_now: g.vcore_mv.load(Ordering::Relaxed) as f64 / 1000.0,
            vbram_now: g.vbram_mv.load(Ordering::Relaxed) as f64 / 1000.0,
            active_now: g.active_now.load(Ordering::Relaxed) as usize,
            margin_now: f64::from_bits(g.margin_now.load(Ordering::Relaxed)),
            predictor_now: {
                let idx = g.predictor_now.load(Ordering::Relaxed) as usize;
                crate::markov::PREDICTOR_NAMES
                    .get(idx)
                    .copied()
                    .unwrap_or("markov")
            },
            queue_depth: g.shards.iter().map(|s| s.len()).sum(),
        }
    }

    /// Aggregate fleet + per-group statistics (live snapshot).
    pub fn stats(&self) -> FleetServingStats {
        let per_group: Vec<GroupServingStats> =
            self.groups.iter().map(|g| self.group_stats(g)).collect();
        let energy: f64 = per_group.iter().map(|g| g.energy_j).sum();
        let nominal: f64 = per_group.iter().map(|g| g.nominal_energy_j).sum();
        FleetServingStats {
            completed: per_group.iter().map(|g| g.completed).sum(),
            rejected: per_group.iter().map(|g| g.rejected).sum(),
            failed: per_group.iter().map(|g| g.failed).sum(),
            stolen_batches: per_group.iter().map(|g| g.stolen_batches).sum(),
            redispatched: per_group.iter().map(|g| g.redispatched).sum(),
            energy_j: energy,
            nominal_energy_j: nominal,
            power_gain: if energy > 0.0 { nominal / energy } else { 1.0 },
            violation_rate: per_group
                .iter()
                .map(|g| g.violation_rate)
                .fold(0.0, f64::max),
            epochs: per_group.iter().map(|g| g.epochs).max().unwrap_or(0),
            per_group,
        }
    }

    /// Stop accepting work, drain every shard, join workers and the CC,
    /// and return the final report with per-group epoch traces. Gated
    /// instances are ungated first so their workers wake and help drain.
    pub fn shutdown(mut self) -> Result<FleetServingReport> {
        // Release pairs with the workers' Acquire load: every
        // `admitted.inc()` sequenced before this call is visible to a
        // worker that observes the flag, so the admitted == completed +
        // failed drain invariant cannot read a stale admitted count.
        self.shutdown.store(true, Ordering::Release);
        for g in &self.groups {
            for s in &g.shards {
                s.set_gated(false);
                s.set_failed(false);
                s.wake_all();
            }
        }
        // Under VirtualClock the joining thread must leave the scheduling
        // set while workers and the CC drain — a Running-but-blocked
        // joiner would stop virtual time for everyone. resume() must run
        // on every path, so joins collect errors instead of early-return.
        self.cfg.clock.suspend_current();
        let mut worker_panicked = false;
        for w in self.workers.drain(..) {
            worker_panicked |= w.join().is_err();
        }
        let controller = self.controller.take().map(|c| c.join());
        self.cfg.clock.resume_current();
        anyhow::ensure!(!worker_panicked, "worker panicked");
        let (epoch_records, decision_records) = match controller {
            Some(Ok(output)) => output,
            Some(Err(_)) => anyhow::bail!("controller panicked"),
            None => (Vec::new(), Vec::new()),
        };
        Ok(FleetServingReport { stats: self.stats(), epoch_records, decision_records })
    }
}

/// Drive a scenario against a running fleet: one scenario step per fleet
/// epoch, offered load per group = `trace · share · peak_rps`, spread
/// over 16 bursts per epoch, plus one epoch of drain time at the end.
/// Returns the number of accepted submissions. Shared by the
/// `serve-fleet` CLI subcommand, `examples/fleet_serving.rs` and the
/// `simtest` virtual-time harness.
///
/// Pacing follows the *fleet's* clock, so under a
/// [`VirtualClock`](crate::clock::VirtualClock) the whole replay runs in
/// simulation time. Every stochastic input derives from `seed` — payload
/// streams are forked per tenant so one tenant's draws do not depend on
/// its neighbours' model dims or submission order — which makes two runs
/// with the same seed bit-identical.
pub fn drive_scenario(
    fleet: &FleetServing,
    scenario: &crate::workload::Scenario,
    peak_rps: f64,
    seed: u64,
) -> u64 {
    let epoch = fleet.cfg.epoch;
    let clock = fleet.clock().clone();
    let faults = fleet.cfg.faults.clone();
    let mut root = crate::util::prng::Rng::new(seed);
    let mut payload_rngs: Vec<crate::util::prng::Rng> = (0..scenario.tenants.len())
        .map(|i| root.fork(i as u64 + 1))
        .collect();
    let mut accepted = 0u64;
    for step in 0..scenario.steps() {
        let epoch_start = clock.now();
        let targets: Vec<usize> = scenario
            .tenants
            .iter()
            .map(|t| {
                // Correlated surges scale every tenant's target together;
                // the factor is exactly 1.0 outside surge windows, so the
                // multiply is bitwise-neutral on fault-free plans.
                (t.trace.loads[step]
                    * t.share
                    * peak_rps
                    * epoch.as_secs_f64()
                    * faults.surge_multiplier(step))
                .round() as usize
            })
            .collect();
        let bursts = 16usize;
        let gap = epoch / bursts as u32;
        for b in 0..bursts {
            for (gi, &target) in targets.iter().enumerate() {
                let from = (b * target) / bursts;
                let upto = ((b + 1) * target) / bursts;
                for _ in from..upto {
                    let payload = payload_rngs[gi].normal_vec_f32(fleet.in_dim(gi));
                    if fleet.submit(gi, payload).is_ok() {
                        accepted += 1;
                    }
                }
            }
            clock.sleep(gap);
        }
        // Keep epochs aligned even if submission ran long on a wall
        // clock; the saturating remainder avoids a Duration-underflow
        // panic. Under virtual time submissions are free, so this sleeps
        // the exact remainder and epochs stay perfectly phase-aligned
        // with the CC.
        let elapsed = clock.now().saturating_sub(epoch_start);
        let remainder = clock::ticks(epoch).saturating_sub(elapsed);
        if remainder > 0 {
            clock.sleep(clock::to_duration(remainder));
        }
    }
    clock.sleep(epoch); // drain window
    accepted
}

/// Render a fleet report as aligned-table rows (header, one row per
/// group, fleet totals last) for `report::table`.
pub fn fleet_report_rows(stats: &FleetServingStats) -> Vec<Vec<String>> {
    let mut rows = vec![crate::report::row([
        "group", "share", "backend", "active", "pred", "margin", "done", "rejected",
        "failed", "stolen", "redisp", "p50_ms", "p99_ms", "gain", "violations%",
    ])];
    for g in &stats.per_group {
        rows.push(vec![
            g.name.clone(),
            format!("{:.2}", g.share),
            g.backend.to_string(),
            format!("{}/{}", g.active_now, g.n_instances),
            g.predictor_now.to_string(),
            format!("{:.2}", g.margin_now),
            g.completed.to_string(),
            g.rejected.to_string(),
            g.failed.to_string(),
            g.stolen_batches.to_string(),
            g.redispatched.to_string(),
            format!("{:.1}", g.p50_latency_s * 1e3),
            format!("{:.1}", g.p99_latency_s * 1e3),
            format!("{:.2}x", g.power_gain),
            format!("{:.1}", g.violation_rate * 100.0),
        ]);
    }
    rows.push(vec![
        "fleet".into(),
        "1.00".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        stats.completed.to_string(),
        stats.rejected.to_string(),
        stats.failed.to_string(),
        stats.stolen_batches.to_string(),
        stats.redispatched.to_string(),
        "-".into(),
        "-".into(),
        format!("{:.2}x", stats.power_gain),
        format!("{:.1}", stats.violation_rate * 100.0),
    ]);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::VirtualClock;
    use crate::vscale::{ElasticConfig, ElasticLut};

    fn reqs(n: usize) -> Vec<Request> {
        // Timestamps route through the injected clock; unit tests pin them
        // to tick 0 so no helper ever reads wall time mid-test.
        (0..n)
            .map(|i| Request { id: i as u64, payload: vec![0.0; 2], submitted: 0 })
            .collect()
    }

    #[test]
    fn claim_batch_steals_from_deepest_sibling_when_idle() {
        let shards: Vec<Arc<ShardQueue>> =
            (0..3).map(|_| Arc::new(ShardQueue::new(64))).collect();
        for r in reqs(8) {
            shards[0].try_push(r).unwrap();
        }
        for r in reqs(2) {
            shards[1].try_push(r).unwrap();
        }
        // Worker 2 is idle; it must steal ~half of shard 0's backlog.
        let (batch, stolen) =
            claim_batch(&shards, 2, 16, Duration::from_millis(1), true);
        assert!(stolen, "idle worker must steal");
        assert_eq!(batch.len(), 4);
        assert_eq!(shards[0].len(), 4);
        assert_eq!(shards[1].len(), 2, "shallower sibling untouched");
    }

    #[test]
    fn claim_batch_prefers_home_shard_and_respects_steal_flag() {
        let shards: Vec<Arc<ShardQueue>> =
            (0..2).map(|_| Arc::new(ShardQueue::new(64))).collect();
        for r in reqs(3) {
            shards[1].try_push(r).unwrap();
        }
        shards[0]
            .try_push(Request { id: 99, payload: vec![], submitted: 0 })
            .unwrap();
        let (batch, stolen) =
            claim_batch(&shards, 0, 16, Duration::from_millis(1), true);
        assert!(!stolen, "home work comes first");
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 99);

        // With stealing disabled the idle worker stays empty-handed.
        let (batch, stolen) =
            claim_batch(&shards, 0, 16, Duration::from_millis(1), false);
        assert!(!stolen);
        assert!(batch.is_empty());
        assert_eq!(shards[1].len(), 3);
    }

    #[test]
    fn claim_batch_never_steals_from_a_gated_sibling() {
        let shards: Vec<Arc<ShardQueue>> =
            (0..3).map(|_| Arc::new(ShardQueue::new(64))).collect();
        for r in reqs(8) {
            shards[1].try_push(r).unwrap();
        }
        shards[1].set_gated(true);
        for r in reqs(2) {
            shards[2].try_push(r).unwrap();
        }
        // Worker 0 is idle; the deepest shard is gated, so it must steal
        // from the shallower active sibling instead.
        let (batch, stolen) =
            claim_batch(&shards, 0, 16, Duration::from_millis(1), true);
        assert!(stolen);
        assert_eq!(batch.len(), 1, "steals half of the active sibling's 2");
        assert_eq!(shards[1].len(), 8, "gated backlog is left for the CC drain");
    }

    #[test]
    fn voltage_gauges_round_to_millivolts() {
        // 0.7f64 is stored as 0.69999999999999996: truncation used to
        // publish 699 mV for a 700 mV operating point.
        assert_eq!(volts_to_mv(0.7), 700);
        assert_eq!(volts_to_mv(0.8999999999), 900);
        assert_eq!(volts_to_mv(0.95), 950);
        assert_eq!(volts_to_mv(0.5), 500);
        assert_eq!(volts_to_mv(0.6493), 649);
    }

    #[test]
    fn published_gauges_pin_to_the_lut_entry() {
        // With no load, no warmup and no PJRT refinement, the CC must
        // publish exactly the bin-0 elastic LUT entry — voltages rounded
        // to millivolts, not truncated. Runs under VirtualClock: the old
        // version polled wall time with a 10 s deadline loop; here the CC
        // fires at virtual ticks 30/60/90 ms and sleeping 100 virtual ms
        // yields *exactly* three epochs, deterministically, in
        // microseconds of wall time.
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _driver = ActorScope::enter(&clock, "test-driver");
        let cfg = FleetServingConfig {
            groups: vec![GroupConfig {
                benchmark: "tabla".into(),
                share: 1.0,
                n_instances: 2,
                qos_target: None,
            }],
            epoch: Duration::from_millis(30),
            warmup_epochs: 0,
            selector_via_pjrt: false,
            clock: clock.clone(),
            ..Default::default()
        };
        let platform = build_platform(
            "tabla",
            PlatformConfig::default(),
            Policy::Dvfs(cfg.mode),
        )
        .unwrap();
        let lut = ElasticLut::build(
            platform.optimizer_ref(),
            &ElasticConfig {
                m_bins: cfg.m_bins,
                margin_t: cfg.margin_t,
                mode: cfg.mode,
                n_instances: 2,
                residual: cfg.pg_residual,
                policy: cfg.capacity_policy,
                latency_cap_sw: f64::INFINITY,
            },
        );
        let want = lut.entries[0];

        let fleet = FleetServing::start(cfg, "sim-no-artifacts".into()).unwrap();
        clock.sleep(Duration::from_millis(100));
        let stats = fleet.stats();
        assert_eq!(stats.per_group[0].epochs, 3, "CC epochs at 30/60/90 virtual ms");
        let g = &stats.per_group[0];
        let mv = |v: f64| volts_to_mv(v) as f64 / 1000.0;
        assert!(
            (g.vcore_now - mv(want.point.vcore)).abs() < 1e-9,
            "vcore gauge {} vs LUT {}",
            g.vcore_now,
            want.point.vcore
        );
        assert!(
            (g.vbram_now - mv(want.point.vbram)).abs() < 1e-9,
            "vbram gauge {} vs LUT {}",
            g.vbram_now,
            want.point.vbram
        );
        assert!((g.freq_ratio_now - want.freq_ratio).abs() < 1e-12);
        assert_eq!(g.active_now, want.n_active);
        // Static configuration: the new prediction surface reports the
        // fixed margin and the Markov predictor, in stats and gauges.
        assert!((g.margin_now - 0.05).abs() < 1e-12, "margin {}", g.margin_now);
        assert_eq!(g.predictor_now, "markov");
        assert!(
            (fleet.registry().gauge("tabla.margin_now").get() - 0.05).abs() < 1e-12,
            "margin gauge must be published"
        );
        assert_eq!(
            fleet.registry().gauge("tabla.predictor_now").get(),
            crate::markov::PredictorKind::index_of_name("markov") as f64
        );
        fleet.shutdown().unwrap();
    }

    #[test]
    fn ensemble_gauge_reports_the_active_member_never_ensemble() {
        // Regression (ISSUE 5 satellite): the live path used to seed the
        // predictor_now index from the configured kind, so stats read
        // before the first CC epoch reported the literal "ensemble"
        // where the offline path reports the active member.
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _driver = ActorScope::enter(&clock, "test-driver");
        let cfg = FleetServingConfig {
            groups: vec![GroupConfig {
                benchmark: "tabla".into(),
                share: 1.0,
                n_instances: 2,
                qos_target: None,
            }],
            epoch: Duration::from_millis(20),
            warmup_epochs: 0,
            selector_via_pjrt: false,
            predictor: PredictorKind::Ensemble,
            clock: clock.clone(),
            ..Default::default()
        };
        let fleet = FleetServing::start(cfg, "sim-no-artifacts".into()).unwrap();
        // Before the first CC epoch: the startup member, not "ensemble".
        assert_eq!(fleet.stats().per_group[0].predictor_now, "markov");
        clock.sleep(Duration::from_millis(100));
        let now = fleet.stats().per_group[0].predictor_now;
        assert_ne!(now, "ensemble", "the gauge must always name a member");
        assert!(
            crate::markov::PREDICTOR_NAMES[1..].contains(&now),
            "unknown member {now}"
        );
        // The registry gauge publishes the member's index table entry.
        assert_eq!(
            fleet.registry().gauge("tabla.predictor_now").get(),
            PredictorKind::index_of_name(now) as f64
        );
        fleet.shutdown().unwrap();
    }

    #[test]
    fn start_validates_group_shares() {
        let cfg = FleetServingConfig {
            groups: vec![GroupConfig {
                benchmark: "tabla".into(),
                share: 0.5,
                n_instances: 1,
                qos_target: None,
            }],
            ..Default::default()
        };
        assert!(FleetServing::start(cfg, "artifacts".into()).is_err());
        let cfg = FleetServingConfig { groups: vec![], ..Default::default() };
        assert!(FleetServing::start(cfg, "artifacts".into()).is_err());
        let cfg = FleetServingConfig {
            groups: vec![GroupConfig {
                benchmark: "not-a-benchmark".into(),
                share: 1.0,
                n_instances: 1,
                qos_target: None,
            }],
            ..Default::default()
        };
        assert!(FleetServing::start(cfg, "artifacts".into()).is_err());
    }

    #[test]
    fn start_validates_fault_plan_and_qos_tiers() {
        // A board failure naming a shard outside the group's layout must
        // be refused at start, not discovered mid-run.
        let cfg = FleetServingConfig {
            faults: Arc::new(FaultPlan {
                board_failures: vec![crate::workload::BoardFailure {
                    group: 0,
                    shard: 5,
                    fail_epoch: 1,
                    recover_epoch: 2,
                }],
                ..Default::default()
            }),
            ..Default::default()
        };
        assert!(FleetServing::start(cfg, "artifacts".into()).is_err());
        let cfg = FleetServingConfig {
            faults: Arc::new(FaultPlan {
                stragglers: vec![crate::workload::StragglerWindow {
                    group: 3,
                    shard: 0,
                    from_epoch: 1,
                    until_epoch: 2,
                    slowdown: 2.0,
                }],
                ..Default::default()
            }),
            ..Default::default()
        };
        assert!(FleetServing::start(cfg, "artifacts".into()).is_err());
        let cfg = FleetServingConfig {
            groups: vec![GroupConfig {
                benchmark: "tabla".into(),
                share: 1.0,
                n_instances: 2,
                qos_target: Some(1.5),
            }],
            ..Default::default()
        };
        assert!(FleetServing::start(cfg, "artifacts".into()).is_err());
    }

    #[test]
    fn failed_board_is_gated_drained_and_recovers_without_dropping_work() {
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _driver = ActorScope::enter(&clock, "test-driver");
        let faults = Arc::new(FaultPlan {
            board_failures: vec![crate::workload::BoardFailure {
                group: 0,
                shard: 1,
                fail_epoch: 1,
                recover_epoch: 3,
            }],
            ..Default::default()
        });
        let cfg = FleetServingConfig {
            groups: vec![GroupConfig {
                benchmark: "tabla".into(),
                share: 1.0,
                n_instances: 2,
                qos_target: None,
            }],
            epoch: Duration::from_millis(20),
            warmup_epochs: 0,
            selector_via_pjrt: false,
            faults,
            clock: clock.clone(),
            ..Default::default()
        };
        let fleet = FleetServing::start(cfg, "sim-no-artifacts".into()).unwrap();
        let in_dim = fleet.in_dim(0);
        for step in 0..5 {
            for _ in 0..8 {
                let _ = fleet.submit(0, vec![0.1; in_dim]);
            }
            clock.sleep(Duration::from_millis(20));
            if step == 1 {
                // Inside the failure window the downed shard is flagged
                // *and* gated, so dispatch, stealing and its worker all
                // avoid it while the CC re-dispatches its backlog.
                assert!(fleet.groups[0].shards[1].is_failed());
                assert!(fleet.groups[0].shards[1].is_gated());
                assert_eq!(fleet.stats().per_group[0].failed_boards_now, 1);
            }
        }
        clock.sleep(Duration::from_millis(60));
        let report = fleet.shutdown().unwrap();
        let g = &report.stats.per_group[0];
        assert_eq!(
            g.admitted,
            g.completed + g.failed,
            "failover must uphold the drain invariant"
        );
        let recs = &report.epoch_records[0];
        assert_eq!(recs[0].n_failed, 0, "epoch 0 is served before any CC pass");
        assert!(
            recs.iter().any(|r| r.n_failed == 1),
            "the failure window must appear in the trace"
        );
        assert!(
            recs.iter().all(|r| r.slow_factor == 1.0),
            "no straggler windows in this plan"
        );
        let last = recs.last().unwrap();
        assert_eq!(last.n_failed, 0, "the board recovers before shutdown");
    }

    #[test]
    fn straggler_window_scales_capacity_and_preserves_conservation() {
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _driver = ActorScope::enter(&clock, "test-driver");
        let faults = Arc::new(FaultPlan {
            stragglers: vec![crate::workload::StragglerWindow {
                group: 0,
                shard: 0,
                from_epoch: 1,
                until_epoch: 3,
                slowdown: 2.0,
            }],
            ..Default::default()
        });
        let cfg = FleetServingConfig {
            groups: vec![GroupConfig {
                benchmark: "tabla".into(),
                share: 1.0,
                n_instances: 2,
                qos_target: None,
            }],
            epoch: Duration::from_millis(20),
            warmup_epochs: 0,
            selector_via_pjrt: false,
            faults,
            clock: clock.clone(),
            ..Default::default()
        };
        let fleet = FleetServing::start(cfg, "sim-no-artifacts".into()).unwrap();
        let in_dim = fleet.in_dim(0);
        for _ in 0..5 {
            for _ in 0..4 {
                let _ = fleet.submit(0, vec![0.1; in_dim]);
            }
            clock.sleep(Duration::from_millis(20));
        }
        clock.sleep(Duration::from_millis(60));
        let report = fleet.shutdown().unwrap();
        let g = &report.stats.per_group[0];
        assert_eq!(g.admitted, g.completed + g.failed);
        let recs = &report.epoch_records[0];
        assert!(
            recs.iter().any(|r| r.slow_factor < 1.0),
            "the straggler window must shrink the modeled capacity"
        );
        assert!(recs.iter().all(|r| r.slow_factor > 0.0 && r.slow_factor <= 1.0));
        assert!(recs.iter().all(|r| r.n_failed == 0));
    }
}
