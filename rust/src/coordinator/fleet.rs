//! Multi-tenant sharded serving — fleet composition root (DESIGN.md
//! S11.5, S21).
//!
//! Since the fleet-of-fleets split this file owns *composition only*; the
//! three layers it assembles each live in their own module with an
//! explicit source of truth:
//!
//! * [`topology`](super::topology) — [`FleetTopology`], the versioned,
//!   pure-data map of groups → nodes → shards behind a
//!   [`TopologyStore`]; every placement question is answered here.
//! * [`node`](super::node) — per-node data planes ([shard queues +
//!   dispatcher + workers]) and the node CC thread running the *identical*
//!   [`GroupController`](crate::control::GroupController) decision loop
//!   per hosted group, with migration = gate + drain + re-dispatch +
//!   controller hand-off.
//! * [`router`](super::router) — submit routing across nodes (least
//!   loaded among hosting nodes; work stealing stays node-local) and the
//!   opt-in saturation rebalancer.
//!
//! A [`FleetServing`] with the default `nodes: 1` is the legacy
//! single-process coordinator, bit-identical: same actor registration
//! order, same epoch-pass float expressions, same submit placement —
//! every pre-split test, scenario and golden replays unchanged. With
//! `nodes: N` the same groups spread round-robin across N node agents,
//! and `tests/control_equivalence.rs` holds the distributed decision
//! logs to the offline `Platform` replay.
//!
//! Each group keeps its own predictor, voltage LUT and DVFS domain; the
//! elastic capacity manager, fault-plan semantics and the
//! `admitted == completed + failed` drain invariant are unchanged from
//! the monolith (DESIGN.md S6.1, S20) — the epoch pass moved verbatim
//! into `node::GroupCc::run_epoch`. All sleeping, waiting and
//! timestamping goes through the configured
//! [`Clock`](crate::clock::Clock) (DESIGN.md S18), so a fleet on a
//! [`VirtualClock`](crate::clock::VirtualClock) — any node count — is a
//! deterministic discrete-event simulation: [`drive_scenario`] replays
//! epochs in virtual time and two runs with the same seed produce
//! byte-identical [`EpochRecord`] traces (`simtest`).

use std::time::Duration;

use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::Arc;

use anyhow::Result;

use crate::clock::{self, Clock};

use super::backend::InferenceBackend;
use super::dispatch::{DispatchPolicy, Dispatcher};
use super::node::{self, GroupCc, GroupSlice, Handover, NodeCtx, NodeShared, WorkerEnv};
use super::router::{RebalanceConfig, Router};
use super::shard::ShardQueue;
use super::topology::{FleetTopology, MigrationPlan, TopologySnapshot, TopologyStore, MAX_NODES};
use super::{EpochRecord, Request, SubmitError};
use crate::control::DecisionRecord;
use crate::markov::PredictorKind;
use crate::metrics::{Counter, Gauge, Histogram, Registry};
use crate::platform::{build_platform, PlatformConfig, Policy};
use crate::power::DesignPower;
use crate::vscale::{CapacityPolicy, Mode, Optimizer};
use crate::workload::FaultPlan;

/// Normalized nominal service clock (Hz); only the ratio to the published
/// frequency matters for the simulated occupancy.
pub(crate) const F_NOM_HZ: f64 = 1.0e8;

/// One tenant group of a live fleet.
#[derive(Clone, Debug)]
pub struct GroupConfig {
    /// Benchmark / artifact variant served by this group.
    pub benchmark: String,
    /// Fraction of fleet traffic this group is provisioned for.
    pub share: f64,
    /// Worker instances (== shards) in this group.
    pub n_instances: usize,
    /// Per-tenant QoS tier (violation-rate target). Only refines an
    /// *enabled* run-level guardband: the effective target is
    /// [`QosTier::effective`](crate::control::QosTier::effective)`(run_target, tier)`,
    /// so with the run-level `qos_target` at `None` (static margin) tiers
    /// are inert and the baselines stay bit-identical.
    pub qos_target: Option<f64>,
}

/// Why a [`FleetServingConfig`] was rejected at construction. Typed so
/// callers (and tests) can distinguish a duplicate tenant from a bad
/// share sum without string matching; [`FleetServing::start_with`] wraps
/// these into its `anyhow` error.
#[derive(Clone, Debug, PartialEq)]
pub enum ConfigError {
    /// The fleet has no groups at all.
    NoGroups,
    /// Two groups share one benchmark/tenant name — later name lookups
    /// ([`FleetServing::group_index`]) would silently shadow the second.
    DuplicateGroup(String),
    /// A group's benchmark name is empty.
    EmptyGroupName,
    /// A group has zero shards/instances.
    ZeroShards(String),
    /// A group's traffic share is not positive.
    NonPositiveShare(String),
    /// Group shares do not sum to ~1 (the actual sum).
    BadShareSum(f64),
    /// A tenant QoS tier target outside `[0, 1)`.
    BadQosTier {
        /// Offending group name.
        group: String,
        /// The rejected target.
        target: f64,
    },
    /// Node count outside `[1, MAX_NODES]`.
    BadNodeCount(usize),
    /// The fault plan is structurally invalid or names shards outside
    /// the fleet layout.
    BadFaultPlan(String),
    /// The migration plan is structurally invalid for this layout.
    BadMigrationPlan(String),
    /// The rebalancer config is unusable (zero sustain or a negative
    /// backlog threshold).
    BadRebalance(String),
    /// The batch knob is unusable (zero nominal batch or a non-finite /
    /// negative dispatch overhead).
    BadBatch(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::NoGroups => write!(f, "fleet needs at least one group"),
            ConfigError::DuplicateGroup(name) => {
                write!(f, "duplicate group name {name:?}: tenant lookups would shadow")
            }
            ConfigError::EmptyGroupName => write!(f, "group benchmark name is empty"),
            ConfigError::ZeroShards(name) => write!(f, "{name}: need >= 1 instance"),
            ConfigError::NonPositiveShare(name) => {
                write!(f, "{name}: share must be positive")
            }
            ConfigError::BadShareSum(sum) => {
                write!(f, "group shares sum to {sum}, expected 1")
            }
            ConfigError::BadQosTier { group, target } => {
                write!(f, "{group}: qos tier target {target} outside [0, 1)")
            }
            ConfigError::BadNodeCount(n) => {
                write!(f, "node count {n} outside [1, {MAX_NODES}]")
            }
            ConfigError::BadFaultPlan(why) => write!(f, "fault plan: {why}"),
            ConfigError::BadMigrationPlan(why) => write!(f, "migration plan: {why}"),
            ConfigError::BadRebalance(why) => write!(f, "rebalance config: {why}"),
            ConfigError::BadBatch(why) => write!(f, "batch config: {why}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Configuration of a multi-tenant serving fleet.
#[derive(Clone, Debug)]
pub struct FleetServingConfig {
    /// Tenant groups; shares must sum to ~1.
    pub groups: Vec<GroupConfig>,
    /// DVFS epoch length (the simulator's τ, compressed for serving runs).
    pub epoch: Duration,
    /// Total queued requests a group may hold, split across its shards.
    pub queue_capacity: usize,
    /// Max wait for the first request of a batch before going idle-check.
    pub batch_timeout: Duration,
    /// Cycles one batch occupies an instance (service time = cycles / f).
    pub cycles_per_batch: f64,
    /// Nominal requests per dispatched inference batch (the backend's
    /// native geometry; the offline twin is
    /// `PlatformConfig::batch_nominal`).
    pub batch_nominal: usize,
    /// Treat batch size as a per-epoch control decision (DESIGN.md S22):
    /// each group's CC publishes bigger batches at low frequency ratios
    /// to amortize per-dispatch overhead, nominal at full speed. Off by
    /// default — fixed-batch fleets replay pre-knob traces byte-for-byte
    /// (the amortization multiplier is an exact 1.0 at the nominal).
    pub adaptive_batch: bool,
    /// Per-dispatch overhead as a fraction of `cycles_per_batch` (weight
    /// swap / DMA setup / pipeline refill) — what
    /// [`batch_amortization`](crate::control::batch_amortization) trades
    /// against batch size, in the worker's service-time charge and the
    /// CC's capacity model alike.
    pub batch_overhead: f64,
    /// Voltage mode for every group's CC decisions.
    pub mode: Mode,
    /// Query the AOT'd Pallas Voltage Selector through PJRT when it is
    /// available (falls back to the native optimizer point otherwise).
    pub selector_via_pjrt: bool,
    /// Markov bins per group predictor.
    pub m_bins: usize,
    /// Throughput margin t for the voltage LUTs.
    pub margin_t: f64,
    /// Pure-training epochs before predictions are trusted.
    pub warmup_epochs: usize,
    /// Shard selection policy on the submit path.
    pub dispatch: DispatchPolicy,
    /// Allow idle workers to steal from sibling shards (node-local).
    pub steal: bool,
    /// How each group's CC trades instance gating against DVFS per epoch
    /// (DESIGN.md S6.1): `Hybrid` is the elastic capacity manager,
    /// `DvfsOnly` / `GatingOnly` are the baselines.
    pub capacity_policy: CapacityPolicy,
    /// Residual power fraction (of nominal) drawn by a gated instance.
    pub pg_residual: f64,
    /// Bounded backlog, in units of one epoch's nominal capacity — the
    /// live twin of the offline `PlatformConfig.max_backlog_steps` (the
    /// cross-path decision-equivalence contract requires the two to
    /// match; both default to 1.0).
    pub max_backlog_steps: f64,
    /// Workload predictor driving every group's CC (DESIGN.md S7):
    /// `Ensemble` runs all predictors shadow-mode per group and switches
    /// the active one with hysteresis.
    pub predictor: PredictorKind,
    /// Epochs per cycle assumed by the periodic predictor member.
    pub predictor_period: usize,
    /// `Some(target)` enables the adaptive QoS-feedback guardband
    /// (DESIGN.md S7.1): the margin shrinks while the observed per-tenant
    /// violation rate stays under `target` and boosts immediately on an
    /// under-prediction. `None` keeps the static `margin_t`.
    pub qos_target: Option<f64>,
    /// Deterministic fault-injection schedule (DESIGN.md S20): board
    /// failures gate + drain shards at CC epoch boundaries, straggler
    /// windows stretch worker service time, surge windows scale
    /// [`drive_scenario`]'s offered load. The default empty plan is
    /// bitwise-neutral — every query returns exactly `1.0` / no failure,
    /// so fault-free runs reproduce pre-fault traces byte-for-byte.
    pub faults: Arc<FaultPlan>,
    /// Serving nodes (DESIGN.md S21): groups spread round-robin across
    /// `nodes` node agents, each running the identical CC decision loop
    /// for its hosted groups. The default `1` is the legacy
    /// single-process coordinator, bit-identical to the pre-split path.
    pub nodes: usize,
    /// Deterministic scripted migration schedule (DESIGN.md S21.3): at
    /// each listed epoch the hosting node gates + drains its slice into
    /// the destination's and hands the group's controller over. The
    /// default empty plan is bitwise-neutral.
    pub migrations: Arc<MigrationPlan>,
    /// Opt-in saturation rebalancer (DESIGN.md S21.3): `Some(..)` lets a
    /// node migrate a group away after sustained modeled backlog. The
    /// default `None` keeps placements fixed so every legacy run and
    /// equivalence contract is untouched.
    pub rebalance: Option<RebalanceConfig>,
    /// Time source for every wait/sleep/timestamp (DESIGN.md S18):
    /// `clock::wall()` for live serving, a
    /// [`VirtualClock`](crate::clock::VirtualClock) for deterministic
    /// simulation. Under a virtual clock the starting thread must already
    /// be a registered actor
    /// ([`ActorScope::enter`](crate::clock::ActorScope::enter)).
    pub clock: Arc<dyn Clock>,
}

impl Default for FleetServingConfig {
    fn default() -> Self {
        FleetServingConfig {
            groups: vec![GroupConfig {
                benchmark: "tabla".into(),
                share: 1.0,
                n_instances: 2,
                qos_target: None,
            }],
            epoch: Duration::from_millis(200),
            queue_capacity: 4096,
            batch_timeout: Duration::from_millis(5),
            cycles_per_batch: 2.0e5,
            batch_nominal: 16,
            adaptive_batch: false,
            batch_overhead: 0.1,
            mode: Mode::Proposed,
            selector_via_pjrt: true,
            m_bins: 10,
            margin_t: 0.05,
            warmup_epochs: 2,
            dispatch: DispatchPolicy::LeastLoaded,
            steal: true,
            capacity_policy: CapacityPolicy::Hybrid,
            pg_residual: 0.02,
            max_backlog_steps: 1.0,
            predictor: PredictorKind::Markov,
            predictor_period: 96,
            qos_target: None,
            faults: Arc::new(FaultPlan::default()),
            nodes: 1,
            migrations: Arc::new(MigrationPlan::default()),
            rebalance: None,
            clock: clock::wall(),
        }
    }
}

impl FleetServingConfig {
    /// Structural validation, run by [`FleetServing::start_with`] before
    /// any thread spawns: group names (non-empty, unique), shard counts,
    /// shares, QoS tiers, node count, and the fault / migration plans
    /// against this layout. Typed errors so callers can match on the
    /// exact defect.
    pub fn validate(&self) -> std::result::Result<(), ConfigError> {
        if self.groups.is_empty() {
            return Err(ConfigError::NoGroups);
        }
        for g in &self.groups {
            if g.benchmark.is_empty() {
                return Err(ConfigError::EmptyGroupName);
            }
            if g.n_instances == 0 {
                return Err(ConfigError::ZeroShards(g.benchmark.clone()));
            }
            if g.share <= 0.0 {
                return Err(ConfigError::NonPositiveShare(g.benchmark.clone()));
            }
            if let Some(t) = g.qos_target {
                if !(0.0..1.0).contains(&t) {
                    return Err(ConfigError::BadQosTier {
                        group: g.benchmark.clone(),
                        target: t,
                    });
                }
            }
        }
        // Duplicate tenant names: group_index()/submit_to() resolve by
        // name and would silently shadow the later group.
        for (i, g) in self.groups.iter().enumerate() {
            if self.groups[..i].iter().any(|o| o.benchmark == g.benchmark) {
                return Err(ConfigError::DuplicateGroup(g.benchmark.clone()));
            }
        }
        let share_sum: f64 = self.groups.iter().map(|g| g.share).sum();
        if (share_sum - 1.0).abs() >= 1e-6 {
            return Err(ConfigError::BadShareSum(share_sum));
        }
        if self.nodes == 0 || self.nodes > MAX_NODES {
            return Err(ConfigError::BadNodeCount(self.nodes));
        }
        // Structural plan checks (windows non-empty, slowdowns >= 1, ...)
        // are layout-independent; index bounds are checked against each
        // group's own instance count since groups may differ in size.
        self.faults
            .validate(usize::MAX, usize::MAX)
            .map_err(ConfigError::BadFaultPlan)?;
        for f in &self.faults.board_failures {
            if f.group >= self.groups.len() || f.shard >= self.groups[f.group].n_instances {
                return Err(ConfigError::BadFaultPlan(format!(
                    "board failure ({}, {}) outside the fleet layout",
                    f.group, f.shard
                )));
            }
        }
        for w in &self.faults.stragglers {
            if w.group >= self.groups.len() || w.shard >= self.groups[w.group].n_instances {
                return Err(ConfigError::BadFaultPlan(format!(
                    "straggler ({}, {}) outside the fleet layout",
                    w.group, w.shard
                )));
            }
        }
        self.migrations
            .validate(self.groups.len(), self.nodes)
            .map_err(ConfigError::BadMigrationPlan)?;
        if let Some(rb) = &self.rebalance {
            if rb.sustain == 0 {
                return Err(ConfigError::BadRebalance(
                    "sustain must be >= 1 epoch".into(),
                ));
            }
            if !(rb.min_backlog >= 0.0) {
                return Err(ConfigError::BadRebalance(format!(
                    "min_backlog {} must be >= 0",
                    rb.min_backlog
                )));
            }
        }
        if self.batch_nominal == 0 {
            return Err(ConfigError::BadBatch("batch_nominal must be >= 1".into()));
        }
        if !(self.batch_overhead >= 0.0 && self.batch_overhead.is_finite()) {
            return Err(ConfigError::BadBatch(format!(
                "batch_overhead {} must be finite and >= 0",
                self.batch_overhead
            )));
        }
        Ok(())
    }
}

/// Shared state of one live group — placement-independent: counters,
/// published operating point and latency surface follow the group
/// through migrations, while the queues/dispatcher live per-node in
/// [`GroupSlice`].
pub(super) struct GroupShared {
    pub(super) name: String,
    pub(super) share: f64,
    pub(super) n_instances: usize,
    pub(super) backend_name: &'static str,
    pub(super) in_dim: usize,
    pub(super) out_dim: usize,
    pub(super) batch: usize,
    /// Requests per dispatched batch the CC currently asks workers to
    /// claim (DESIGN.md S22): the configured nominal unless
    /// `adaptive_batch` publishes a bigger one at low frequency. Distinct
    /// from `batch`, the backend artifact's fixed tensor geometry —
    /// workers chunk a claimed set into `batch`-sized dispatches.
    pub(super) batch_now: AtomicU64,
    pub(super) freq_ratio: AtomicU64,
    pub(super) vcore_mv: AtomicU64,
    pub(super) vbram_mv: AtomicU64,
    pub(super) active_now: AtomicU64,
    /// Currently applied throughput margin (f64 bits).
    pub(super) margin_now: AtomicU64,
    /// Index of the active prediction source in
    /// [`crate::markov::PREDICTOR_NAMES`].
    pub(super) predictor_now: AtomicU64,
    /// Requests successfully placed on some shard. Shutdown-drain
    /// invariant: workers may exit only once
    /// `admitted == completed + failed` — queue emptiness alone is racy
    /// because the CC's gated-shard drain (and a migration hand-off)
    /// holds requests outside any queue while re-dispatching them.
    pub(super) admitted: Counter,
    pub(super) completed: Counter,
    pub(super) rejected: Counter,
    pub(super) failed: Counter,
    pub(super) stolen_batches: Counter,
    /// Requests the CC pulled off a gated or failed shard — or a
    /// migrating slice — and re-queued (failover re-dispatch; never a
    /// drop).
    pub(super) redispatched: Counter,
    /// Cross-node migrations this group has undergone.
    pub(super) migrated: Counter,
    /// Boards of this group currently failed by the fault plan.
    pub(super) failed_boards: AtomicU64,
    pub(super) violations: Counter,
    pub(super) epochs: Counter,
    pub(super) latency_us: Histogram,
    pub(super) energy_j: Gauge,
    pub(super) nominal_energy_j: Gauge,
}

impl GroupShared {
    pub(super) fn freq_ratio(&self) -> f64 {
        f64::from_bits(self.freq_ratio.load(Ordering::Relaxed))
    }
}

/// Round a rail voltage to integer millivolts for the published gauges.
/// Truncation would report e.g. 0.7 V (stored as 0.6999…) as 699 mV.
pub(crate) fn volts_to_mv(v: f64) -> u64 {
    (v * 1000.0).round() as u64
}

/// Per-group serving statistics (live or final).
#[derive(Clone, Debug)]
pub struct GroupServingStats {
    /// Group / benchmark name.
    pub name: String,
    /// Provisioned traffic share.
    pub share: f64,
    /// Worker instances in the group.
    pub n_instances: usize,
    /// Inference backend the group's workers use (`pjrt` or `native`).
    pub backend: &'static str,
    /// Name of the node currently hosting the group (DESIGN.md S21).
    pub node_now: String,
    /// Requests accepted onto some shard (the drain invariant:
    /// `admitted == completed + failed` at shutdown).
    pub admitted: u64,
    /// Requests served to completion.
    pub completed: u64,
    /// Requests refused by backpressure.
    pub rejected: u64,
    /// Requests dropped because the inference backend errored.
    pub failed: u64,
    /// Batches obtained by work stealing.
    pub stolen_batches: u64,
    /// Requests re-dispatched off gated/failed/migrating shards.
    pub redispatched: u64,
    /// Cross-node migrations this group has undergone.
    pub migrated: u64,
    /// Boards currently failed by the fault plan.
    pub failed_boards_now: usize,
    /// Mean end-to-end latency (s).
    pub mean_latency_s: f64,
    /// Median end-to-end latency (s).
    pub p50_latency_s: f64,
    /// 99th-percentile end-to-end latency (s).
    pub p99_latency_s: f64,
    /// Energy integrated at the CC's operating points (J).
    pub energy_j: f64,
    /// Energy the group would have drawn at nominal V/f (J).
    pub nominal_energy_j: f64,
    /// Paper's headline metric: nominal energy / actual energy.
    pub power_gain: f64,
    /// Fraction of epochs whose demand exceeded served capacity.
    pub violation_rate: f64,
    /// DVFS epochs elapsed.
    pub epochs: u64,
    /// Currently published f / f_nom.
    pub freq_ratio_now: f64,
    /// Currently published core-rail voltage (V).
    pub vcore_now: f64,
    /// Currently published BRAM-rail voltage (V).
    pub vbram_now: f64,
    /// Instances currently active (not gated by the elastic manager).
    pub active_now: usize,
    /// Requests per dispatched batch the CC currently publishes (the
    /// configured nominal unless `adaptive_batch` is on).
    pub batch_now: usize,
    /// Throughput margin the CC currently applies (static `margin_t` or
    /// the adaptive guardband's ladder level).
    pub margin_now: f64,
    /// Prediction source currently active (the ensemble reports its
    /// member).
    pub predictor_now: &'static str,
    /// Requests currently queued across the group's shards (all nodes).
    pub queue_depth: usize,
}

/// Fleet-level aggregate over all groups.
#[derive(Clone, Debug)]
pub struct FleetServingStats {
    /// Per-group breakdown.
    pub per_group: Vec<GroupServingStats>,
    /// Total completed requests.
    pub completed: u64,
    /// Total rejected requests.
    pub rejected: u64,
    /// Total backend-failed requests.
    pub failed: u64,
    /// Total stolen batches.
    pub stolen_batches: u64,
    /// Total failover re-dispatches.
    pub redispatched: u64,
    /// Total cross-node migrations.
    pub migrated: u64,
    /// Total integrated energy (J).
    pub energy_j: f64,
    /// Total nominal-baseline energy (J).
    pub nominal_energy_j: f64,
    /// Fleet power gain (nominal energy / actual energy).
    pub power_gain: f64,
    /// Worst per-group violation rate (QoS is per-tenant).
    pub violation_rate: f64,
    /// DVFS epochs elapsed (max over groups).
    pub epochs: u64,
}

/// Final outcome of a fleet serving run.
#[derive(Clone, Debug)]
pub struct FleetServingReport {
    /// Aggregate + per-group statistics at shutdown.
    pub stats: FleetServingStats,
    /// Per-group CC epoch traces (index-aligned with `stats.per_group`);
    /// continuous across migrations — the trace travels with the
    /// controller.
    pub epoch_records: Vec<Vec<EpochRecord>>,
    /// Per-group control-plane decision logs (index-aligned with
    /// `stats.per_group`): the exact [`DecisionRecord`] sequence each
    /// group's [`GroupController`](crate::control::GroupController)
    /// produced, one per epoch, wherever the group was hosted. Replaying
    /// the observed epoch loads through the offline `platform::Platform`
    /// must reproduce these sequences identically
    /// (`tests/control_equivalence.rs`).
    pub decision_records: Vec<Vec<DecisionRecord>>,
}

/// The live multi-tenant coordinator: topology + node agents + router.
pub struct FleetServing {
    /// Configuration the fleet was started with.
    pub cfg: FleetServingConfig,
    groups: Vec<Arc<GroupShared>>,
    nodes: Vec<Arc<NodeShared>>,
    store: Arc<TopologyStore>,
    router: Router,
    handover: Arc<Handover>,
    registry: Arc<Registry>,
    shutdown: Arc<AtomicBool>,
    workers: Vec<std::thread::JoinHandle<()>>,
    controllers: Vec<std::thread::JoinHandle<Vec<GroupCc>>>,
    rejected_total: Arc<Counter>,
    next_id: AtomicU64,
}

impl FleetServing {
    /// Start a fleet, building each group's power model and optimizer from
    /// its benchmark name (`platform::build_platform`).
    pub fn start(cfg: FleetServingConfig, artifacts_dir: std::path::PathBuf) -> Result<Self> {
        let mut built = Vec::with_capacity(cfg.groups.len());
        for g in &cfg.groups {
            let platform = build_platform(
                &g.benchmark,
                PlatformConfig::default(),
                Policy::Dvfs(cfg.mode),
            )
            .map_err(anyhow::Error::msg)?;
            built.push((platform.design.clone(), platform.optimizer_ref().clone()));
        }
        Self::start_with(cfg, artifacts_dir, built)
    }

    /// Start a fleet with pre-built `(design, optimizer)` pairs, one per
    /// group (index-aligned with `cfg.groups`).
    pub fn start_with(
        cfg: FleetServingConfig,
        artifacts_dir: std::path::PathBuf,
        built: Vec<(DesignPower, Optimizer)>,
    ) -> Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(
            built.len() == cfg.groups.len(),
            "got {} design/optimizer pairs for {} groups",
            built.len(),
            cfg.groups.len()
        );
        // Deterministic virtual-time scheduling needs every participating
        // thread registered; catching a forgotten driver here beats a
        // silent free-running simulation.
        anyhow::ensure!(
            cfg.clock.current_is_actor(),
            "VirtualClock: register the starting thread as an actor first \
             (clock::ActorScope::enter) so the simulation stays deterministic"
        );

        let registry = Arc::new(Registry::new());
        let shutdown = Arc::new(AtomicBool::new(false));

        // ---- per-group shared state (placement-independent) ------------
        let mut groups: Vec<Arc<GroupShared>> = Vec::with_capacity(cfg.groups.len());
        for g in &cfg.groups {
            // Probe once for dims + backend availability; workers re-open
            // their own backend (PJRT clients are not shared across
            // threads).
            let probe = InferenceBackend::open(&artifacts_dir, &g.benchmark);
            groups.push(Arc::new(GroupShared {
                name: g.benchmark.clone(),
                share: g.share,
                n_instances: g.n_instances,
                backend_name: probe.name(),
                in_dim: probe.in_dim(),
                out_dim: probe.out_dim(),
                batch: probe.batch(),
                batch_now: AtomicU64::new(cfg.batch_nominal.max(1) as u64),
                freq_ratio: AtomicU64::new(1.0f64.to_bits()),
                vcore_mv: AtomicU64::new(800),
                vbram_mv: AtomicU64::new(950),
                active_now: AtomicU64::new(g.n_instances as u64),
                margin_now: AtomicU64::new(cfg.margin_t.to_bits()),
                // Seed with the *active member* name so stats queried
                // before the first CC epoch report a real predictor
                // ("markov"), never the literal "ensemble" — the offline
                // path's active_name() semantics.
                predictor_now: AtomicU64::new(PredictorKind::index_of_name(
                    cfg.predictor.initial_active_name(),
                ) as u64),
                admitted: Counter::default(),
                completed: Counter::default(),
                rejected: Counter::default(),
                failed: Counter::default(),
                stolen_batches: Counter::default(),
                redispatched: Counter::default(),
                migrated: Counter::default(),
                failed_boards: AtomicU64::new(0),
                violations: Counter::default(),
                epochs: Counter::default(),
                latency_us: Histogram::latency_us(),
                energy_j: Gauge::default(),
                nominal_energy_j: Gauge::default(),
            }));
        }

        // ---- topology: the single source of truth for placement --------
        let topology = FleetTopology::spread(cfg.groups.clone(), cfg.nodes)
            .map_err(anyhow::Error::new)?;
        let store = Arc::new(TopologyStore::new(topology));

        // ---- per-node data planes --------------------------------------
        // Every node carries a slice for every group so a migration never
        // allocates on the hot path; non-hosted slices start gated (their
        // workers park) and open only when a hand-off lands.
        let nodes: Vec<Arc<NodeShared>> = (0..cfg.nodes)
            .map(|id| {
                let slices = cfg
                    .groups
                    .iter()
                    .enumerate()
                    .map(|(gi, gc)| {
                        let per_shard = cfg.queue_capacity.div_ceil(gc.n_instances);
                        let shards: Vec<Arc<ShardQueue>> = (0..gc.n_instances)
                            .map(|_| {
                                Arc::new(ShardQueue::with_clock(per_shard, cfg.clock.clone()))
                            })
                            .collect();
                        if store.hosting_mask(gi) & (1u64 << id) == 0 {
                            for s in &shards {
                                s.set_gated(true);
                            }
                        }
                        GroupSlice {
                            shards,
                            dispatcher: Dispatcher::new(cfg.dispatch),
                            arrivals_this_epoch: AtomicU64::new(0),
                        }
                    })
                    .collect();
                Arc::new(NodeShared { id, name: format!("node{id}"), slices })
            })
            .collect();

        // ---- control planes, parked for adoption -----------------------
        // Built on the starting thread (pure LUT compute, no clock
        // access) and deposited into the hand-off slots; each node CC
        // adopts its initially-hosted groups at thread start, exactly as
        // a later migration's destination would.
        let handover = Arc::new(Handover::new(cfg.groups.len()));
        for (gi, ((design, optimizer), g)) in built.into_iter().zip(&groups).enumerate() {
            handover.deposit(gi, GroupCc::new(gi, design, optimizer, &cfg, g));
        }

        // ---- workers ---------------------------------------------------
        // Clock actors are registered *here*, on the starting thread, so
        // their ids — and with them every virtual-time scheduling decision
        // — are assigned in deterministic program order (nodes in id
        // order, groups in index order, instances in order; then the node
        // CCs in id order). With one node this is exactly the legacy
        // monolith's order, so the 1-node path schedules identically.
        // Under `ParallelVirtualClock` the same calls also partition the
        // fleet into advance-domains: group gi's workers (all nodes) land
        // in domain gi+1 and the CCs join the driver in control domain 0,
        // so independent groups simulate concurrently between CC-epoch
        // barriers (DESIGN.md S24). The sequential engine ignores the
        // domain tags, keeping registration order — and traces —
        // identical in both modes.
        let mut workers = Vec::new();
        {
            let env = WorkerEnv {
                cfg: &cfg,
                artifacts_dir: &artifacts_dir,
                registry: &registry,
                stop: &shutdown,
                single_node: cfg.nodes == 1,
            };
            for nd in &nodes {
                for (gi, gshared) in groups.iter().enumerate() {
                    for wid in 0..cfg.groups[gi].n_instances {
                        workers.push(node::spawn_worker(&env, nd, gshared, gi, wid));
                    }
                }
            }
        }

        // ---- node controllers (one CC thread per node) -----------------
        let controllers: Vec<std::thread::JoinHandle<Vec<GroupCc>>> = nodes
            .iter()
            .map(|nd| {
                node::spawn_node_cc(NodeCtx {
                    cfg: cfg.clone(),
                    groups: groups.clone(),
                    nodes: nodes.clone(),
                    me: nd.id,
                    store: store.clone(),
                    handover: handover.clone(),
                    registry: registry.clone(),
                    stop: shutdown.clone(),
                    artifacts_dir: artifacts_dir.clone(),
                })
            })
            .collect();

        let router = Router::new(store.clone(), nodes.clone());
        let rejected_total = registry.counter("fleet.rejected");
        Ok(FleetServing {
            cfg,
            groups,
            nodes,
            store,
            router,
            handover,
            registry,
            shutdown,
            workers,
            controllers,
            rejected_total,
            next_id: AtomicU64::new(0),
        })
    }

    /// Number of tenant groups.
    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// Number of serving nodes.
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Benchmark names of the groups, in index order.
    pub fn group_names(&self) -> Vec<String> {
        self.groups.iter().map(|g| g.name.clone()).collect()
    }

    /// Index of the group serving `benchmark`, if any.
    pub fn group_index(&self, benchmark: &str) -> Option<usize> {
        self.groups.iter().position(|g| g.name == benchmark)
    }

    /// Input feature width of a group's model.
    ///
    /// # Panics
    /// Like slice indexing, panics when `group >= n_groups()`; resolve
    /// indices with [`FleetServing::group_index`] first. The *request*
    /// path ([`FleetServing::submit`]) never panics — it returns
    /// [`SubmitError::UnknownGroup`] instead.
    pub fn in_dim(&self, group: usize) -> usize {
        self.groups[group].in_dim
    }

    /// Artifact batch size of a group's model.
    ///
    /// # Panics
    /// Panics when `group >= n_groups()` (see [`FleetServing::in_dim`]).
    pub fn batch(&self, group: usize) -> usize {
        self.groups[group].batch
    }

    /// Requests currently queued across a group's shards, on every node.
    ///
    /// # Panics
    /// Panics when `group >= n_groups()` (see [`FleetServing::in_dim`]).
    pub fn queue_len(&self, group: usize) -> usize {
        let _ = &self.groups[group];
        self.nodes.iter().map(|n| n.slices[group].depth()).sum()
    }

    /// The shared fleet-level metrics registry.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// The fleet's time source (wall or virtual); [`drive_scenario`] paces
    /// epochs on it so scenario replay follows the fleet's notion of time.
    pub fn clock(&self) -> &Arc<dyn Clock> {
        &self.cfg.clock
    }

    /// Live observability copy of the fleet map — placement, health and
    /// per-node load; the `topology` CLI subcommand prints its
    /// [`TopologySnapshot::to_json`] document (DESIGN.md S21.4).
    pub fn topology_snapshot(&self) -> TopologySnapshot {
        self.store.snapshot()
    }

    /// Submit one request to a group. The router picks the hosting node
    /// (lock-free topology read), the node's dispatcher picks the shard.
    /// Errors are typed backpressure-style signals, never aborts:
    /// `UnknownGroup` for an out-of-range index, `BadPayload` for a
    /// wrong-width payload, `QueueFull` when every active shard of the
    /// group is at capacity.
    pub fn submit(
        &self,
        group: usize,
        payload: Vec<f32>,
    ) -> std::result::Result<u64, SubmitError> {
        let g = self
            .groups
            .get(group)
            .ok_or_else(|| SubmitError::UnknownGroup(format!("group index {group}")))?;
        if payload.len() != g.in_dim {
            return Err(SubmitError::BadPayload { expected: g.in_dim, got: payload.len() });
        }
        let slice = &self.nodes[self.router.route(group)].slices[group];
        // The CC's workload counter sees *offered* demand (paper Fig. 9's
        // arrival counter), so rejected requests still push the predictor
        // toward higher frequency — essential under flash-crowd overload,
        // where admitted traffic alone is capped by the current drain rate.
        slice.arrivals_this_epoch.fetch_add(1, Ordering::Relaxed);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request { id, payload, submitted: self.cfg.clock.now() };
        match node::place_request(slice, req) {
            Ok(()) => {
                g.admitted.inc();
                Ok(id)
            }
            Err(e) => {
                g.rejected.inc();
                self.rejected_total.inc();
                Err(e)
            }
        }
    }

    /// Submit by benchmark name (convenience over [`FleetServing::submit`]);
    /// an unknown name returns `Err(SubmitError::UnknownGroup)`.
    pub fn submit_to(
        &self,
        benchmark: &str,
        payload: Vec<f32>,
    ) -> std::result::Result<u64, SubmitError> {
        let gi = self
            .group_index(benchmark)
            .ok_or_else(|| SubmitError::UnknownGroup(benchmark.to_string()))?;
        self.submit(gi, payload)
    }

    fn group_stats(&self, gi: usize, g: &GroupShared) -> GroupServingStats {
        let energy = g.energy_j.get();
        let nominal = g.nominal_energy_j.get();
        let epochs = g.epochs.get();
        GroupServingStats {
            name: g.name.clone(),
            share: g.share,
            n_instances: g.n_instances,
            backend: g.backend_name,
            node_now: self.store.with(|t| {
                t.nodes_hosting(gi)
                    .first()
                    .map(|&n| t.nodes()[n].name.clone())
                    .unwrap_or_default()
            }),
            admitted: g.admitted.get(),
            completed: g.completed.get(),
            rejected: g.rejected.get(),
            failed: g.failed.get(),
            stolen_batches: g.stolen_batches.get(),
            redispatched: g.redispatched.get(),
            migrated: g.migrated.get(),
            failed_boards_now: g.failed_boards.load(Ordering::Relaxed) as usize,
            mean_latency_s: g.latency_us.mean() / 1e6,
            p50_latency_s: g.latency_us.quantile(0.5) / 1e6,
            p99_latency_s: g.latency_us.quantile(0.99) / 1e6,
            energy_j: energy,
            nominal_energy_j: nominal,
            power_gain: if energy > 0.0 { nominal / energy } else { 1.0 },
            violation_rate: g.violations.get() as f64 / epochs.max(1) as f64,
            epochs,
            freq_ratio_now: g.freq_ratio(),
            vcore_now: g.vcore_mv.load(Ordering::Relaxed) as f64 / 1000.0,
            vbram_now: g.vbram_mv.load(Ordering::Relaxed) as f64 / 1000.0,
            active_now: g.active_now.load(Ordering::Relaxed) as usize,
            batch_now: g.batch_now.load(Ordering::Relaxed) as usize,
            margin_now: f64::from_bits(g.margin_now.load(Ordering::Relaxed)),
            predictor_now: {
                let idx = g.predictor_now.load(Ordering::Relaxed) as usize;
                crate::markov::PREDICTOR_NAMES
                    .get(idx)
                    .copied()
                    .unwrap_or("markov")
            },
            queue_depth: self.nodes.iter().map(|n| n.slices[gi].depth()).sum(),
        }
    }

    /// Aggregate fleet + per-group statistics (live snapshot).
    pub fn stats(&self) -> FleetServingStats {
        let per_group: Vec<GroupServingStats> = self
            .groups
            .iter()
            .enumerate()
            .map(|(gi, g)| self.group_stats(gi, g))
            .collect();
        let energy: f64 = per_group.iter().map(|g| g.energy_j).sum();
        let nominal: f64 = per_group.iter().map(|g| g.nominal_energy_j).sum();
        FleetServingStats {
            completed: per_group.iter().map(|g| g.completed).sum(),
            rejected: per_group.iter().map(|g| g.rejected).sum(),
            failed: per_group.iter().map(|g| g.failed).sum(),
            stolen_batches: per_group.iter().map(|g| g.stolen_batches).sum(),
            redispatched: per_group.iter().map(|g| g.redispatched).sum(),
            migrated: per_group.iter().map(|g| g.migrated).sum(),
            energy_j: energy,
            nominal_energy_j: nominal,
            power_gain: if energy > 0.0 { nominal / energy } else { 1.0 },
            violation_rate: per_group
                .iter()
                .map(|g| g.violation_rate)
                .fold(0.0, f64::max),
            epochs: per_group.iter().map(|g| g.epochs).max().unwrap_or(0),
            per_group,
        }
    }

    /// Stop accepting work, drain every shard on every node, join workers
    /// and the node CCs, and return the final report with per-group epoch
    /// traces. Gated instances (including every non-hosting replica) are
    /// ungated first so their workers wake and help drain.
    pub fn shutdown(mut self) -> Result<FleetServingReport> {
        // Release pairs with the workers' Acquire load: every
        // `admitted.inc()` sequenced before this call is visible to a
        // worker that observes the flag, so the admitted == completed +
        // failed drain invariant cannot read a stale admitted count.
        self.shutdown.store(true, Ordering::Release);
        for nd in &self.nodes {
            for slice in &nd.slices {
                for s in &slice.shards {
                    s.set_gated(false);
                    s.set_failed(false);
                    s.wake_all();
                }
            }
        }
        // Under VirtualClock the joining thread must leave the scheduling
        // set while workers and the CCs drain — a Running-but-blocked
        // joiner would stop virtual time for everyone. resume() must run
        // on every path, so joins collect errors instead of early-return.
        self.cfg.clock.suspend_current();
        let mut worker_panicked = false;
        for w in self.workers.drain(..) {
            worker_panicked |= w.join().is_err();
        }
        let mut cc_panicked = false;
        let mut ccs: Vec<GroupCc> = Vec::with_capacity(self.groups.len());
        for c in self.controllers.drain(..) {
            match c.join() {
                Ok(hosted) => ccs.extend(hosted),
                Err(_) => cc_panicked = true,
            }
        }
        self.cfg.clock.resume_current();
        anyhow::ensure!(!worker_panicked, "worker panicked");
        anyhow::ensure!(!cc_panicked, "controller panicked");
        // A hand-off that raced the stop flag leaves its controller
        // parked in the slot, adopted by no one; it still owes records.
        ccs.extend(self.handover.drain());
        let mut epoch_records: Vec<Vec<EpochRecord>> = vec![Vec::new(); self.groups.len()];
        let mut decision_records: Vec<Vec<DecisionRecord>> =
            vec![Vec::new(); self.groups.len()];
        for mut cc in ccs {
            let gi = cc.gi;
            epoch_records[gi] = std::mem::take(&mut cc.records);
            decision_records[gi] = cc.controller.take_decisions();
        }
        Ok(FleetServingReport { stats: self.stats(), epoch_records, decision_records })
    }
}

/// Drive a scenario against a running fleet: one scenario step per fleet
/// epoch, offered load per group = `trace · share · peak_rps`, spread
/// over 16 bursts per epoch, plus one epoch of drain time at the end.
/// Returns the number of accepted submissions. Shared by the
/// `serve-fleet` CLI subcommand and the `simtest` virtual-time harness.
///
/// Pacing follows the *fleet's* clock, so under a
/// [`VirtualClock`](crate::clock::VirtualClock) the whole replay runs in
/// simulation time. Every stochastic input derives from `seed` — payload
/// streams are forked per tenant so one tenant's draws do not depend on
/// its neighbours' model dims or submission order — which makes two runs
/// with the same seed bit-identical, at any node count.
pub fn drive_scenario(
    fleet: &FleetServing,
    scenario: &crate::workload::Scenario,
    peak_rps: f64,
    seed: u64,
) -> u64 {
    let epoch = fleet.cfg.epoch;
    let clock = fleet.clock().clone();
    let faults = fleet.cfg.faults.clone();
    let mut root = crate::util::prng::Rng::new(seed);
    let mut payload_rngs: Vec<crate::util::prng::Rng> = (0..scenario.tenants.len())
        .map(|i| root.fork(i as u64 + 1))
        .collect();
    let mut accepted = 0u64;
    for step in 0..scenario.steps() {
        let epoch_start = clock.now();
        let targets: Vec<usize> = scenario
            .tenants
            .iter()
            .map(|t| {
                // Correlated surges scale every tenant's target together;
                // the factor is exactly 1.0 outside surge windows, so the
                // multiply is bitwise-neutral on fault-free plans.
                (t.trace.loads[step]
                    * t.share
                    * peak_rps
                    * epoch.as_secs_f64()
                    * faults.surge_multiplier(step))
                .round() as usize
            })
            .collect();
        let bursts = 16usize;
        let gap = epoch / bursts as u32;
        for b in 0..bursts {
            for (gi, &target) in targets.iter().enumerate() {
                let from = (b * target) / bursts;
                let upto = ((b + 1) * target) / bursts;
                for _ in from..upto {
                    let payload = payload_rngs[gi].normal_vec_f32(fleet.in_dim(gi));
                    if fleet.submit(gi, payload).is_ok() {
                        accepted += 1;
                    }
                }
            }
            clock.sleep(gap);
        }
        // Keep epochs aligned even if submission ran long on a wall
        // clock; the saturating remainder avoids a Duration-underflow
        // panic. Under virtual time submissions are free, so this sleeps
        // the exact remainder and epochs stay perfectly phase-aligned
        // with the CC.
        let elapsed = clock.now().saturating_sub(epoch_start);
        let remainder = clock::ticks(epoch).saturating_sub(elapsed);
        if remainder > 0 {
            clock.sleep(clock::to_duration(remainder));
        }
    }
    clock.sleep(epoch); // drain window
    accepted
}

/// Render a fleet report as aligned-table rows (header, one row per
/// group, fleet totals last) for `report::table`.
pub fn fleet_report_rows(stats: &FleetServingStats) -> Vec<Vec<String>> {
    let mut rows = vec![crate::report::row([
        "group", "share", "backend", "node", "active", "pred", "margin", "done", "rejected",
        "failed", "stolen", "redisp", "migr", "p50_ms", "p99_ms", "gain", "violations%",
    ])];
    for g in &stats.per_group {
        rows.push(vec![
            g.name.clone(),
            format!("{:.2}", g.share),
            g.backend.to_string(),
            g.node_now.clone(),
            format!("{}/{}", g.active_now, g.n_instances),
            g.predictor_now.to_string(),
            format!("{:.2}", g.margin_now),
            g.completed.to_string(),
            g.rejected.to_string(),
            g.failed.to_string(),
            g.stolen_batches.to_string(),
            g.redispatched.to_string(),
            g.migrated.to_string(),
            format!("{:.1}", g.p50_latency_s * 1e3),
            format!("{:.1}", g.p99_latency_s * 1e3),
            format!("{:.2}x", g.power_gain),
            format!("{:.1}", g.violation_rate * 100.0),
        ]);
    }
    rows.push(vec![
        "fleet".into(),
        "1.00".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        stats.completed.to_string(),
        stats.rejected.to_string(),
        stats.failed.to_string(),
        stats.stolen_batches.to_string(),
        stats.redispatched.to_string(),
        stats.migrated.to_string(),
        "-".into(),
        "-".into(),
        format!("{:.2}x", stats.power_gain),
        format!("{:.1}", stats.violation_rate * 100.0),
    ]);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{ActorScope, VirtualClock};
    use crate::vscale::{ElasticConfig, ElasticLut};

    fn group(benchmark: &str, share: f64, n_instances: usize) -> GroupConfig {
        GroupConfig { benchmark: benchmark.into(), share, n_instances, qos_target: None }
    }

    #[test]
    fn voltage_gauges_round_to_millivolts() {
        // 0.7f64 is stored as 0.69999999999999996: truncation used to
        // publish 699 mV for a 700 mV operating point.
        assert_eq!(volts_to_mv(0.7), 700);
        assert_eq!(volts_to_mv(0.8999999999), 900);
        assert_eq!(volts_to_mv(0.95), 950);
        assert_eq!(volts_to_mv(0.5), 500);
        assert_eq!(volts_to_mv(0.6493), 649);
    }

    #[test]
    fn config_validation_returns_typed_errors() {
        // Duplicate tenant names (the pre-validation config accepted
        // these and group_index() silently shadowed the second group).
        let cfg = FleetServingConfig {
            groups: vec![group("tabla", 0.5, 1), group("tabla", 0.5, 1)],
            ..Default::default()
        };
        assert_eq!(cfg.validate(), Err(ConfigError::DuplicateGroup("tabla".into())));
        // Empty name.
        let cfg = FleetServingConfig { groups: vec![group("", 1.0, 1)], ..Default::default() };
        assert_eq!(cfg.validate(), Err(ConfigError::EmptyGroupName));
        // Zero shards.
        let cfg =
            FleetServingConfig { groups: vec![group("tabla", 1.0, 0)], ..Default::default() };
        assert_eq!(cfg.validate(), Err(ConfigError::ZeroShards("tabla".into())));
        // No groups at all.
        let cfg = FleetServingConfig { groups: vec![], ..Default::default() };
        assert_eq!(cfg.validate(), Err(ConfigError::NoGroups));
        // Bad share sum.
        let cfg =
            FleetServingConfig { groups: vec![group("tabla", 0.5, 1)], ..Default::default() };
        assert_eq!(cfg.validate(), Err(ConfigError::BadShareSum(0.5)));
        // Node count outside [1, MAX_NODES].
        let cfg = FleetServingConfig { nodes: 0, ..Default::default() };
        assert_eq!(cfg.validate(), Err(ConfigError::BadNodeCount(0)));
        // A migration plan naming a group outside the layout.
        let cfg = FleetServingConfig {
            nodes: 2,
            migrations: Arc::new(MigrationPlan {
                moves: vec![super::super::topology::ScriptedMigration {
                    epoch: 1,
                    group: 5,
                    from: 0,
                    to: 1,
                }],
            }),
            ..Default::default()
        };
        assert!(matches!(cfg.validate(), Err(ConfigError::BadMigrationPlan(_))));
        // A rebalancer that would fire on zero sustained epochs.
        let cfg = FleetServingConfig {
            nodes: 2,
            rebalance: Some(RebalanceConfig { min_backlog: 0.5, sustain: 0 }),
            ..Default::default()
        };
        assert!(matches!(cfg.validate(), Err(ConfigError::BadRebalance(_))));
        // A zero nominal batch and a negative/NaN overhead are refused.
        let cfg = FleetServingConfig { batch_nominal: 0, ..Default::default() };
        assert!(matches!(cfg.validate(), Err(ConfigError::BadBatch(_))));
        let cfg = FleetServingConfig { batch_overhead: -0.1, ..Default::default() };
        assert!(matches!(cfg.validate(), Err(ConfigError::BadBatch(_))));
        let cfg = FleetServingConfig { batch_overhead: f64::NAN, ..Default::default() };
        assert!(matches!(cfg.validate(), Err(ConfigError::BadBatch(_))));
        // The default config is valid.
        FleetServingConfig::default().validate().unwrap();
    }

    #[test]
    fn published_gauges_pin_to_the_lut_entry() {
        // With no load, no warmup and no PJRT refinement, the CC must
        // publish exactly the bin-0 elastic LUT entry — voltages rounded
        // to millivolts, not truncated. Runs under VirtualClock: the CC
        // fires at virtual ticks 30/60/90 ms and sleeping 100 virtual ms
        // yields *exactly* three epochs, deterministically, in
        // microseconds of wall time.
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _driver = ActorScope::enter(&clock, "test-driver");
        let cfg = FleetServingConfig {
            groups: vec![group("tabla", 1.0, 2)],
            epoch: Duration::from_millis(30),
            warmup_epochs: 0,
            selector_via_pjrt: false,
            clock: clock.clone(),
            ..Default::default()
        };
        let platform = build_platform(
            "tabla",
            PlatformConfig::default(),
            Policy::Dvfs(cfg.mode),
        )
        .unwrap();
        let lut = ElasticLut::build(
            platform.optimizer_ref(),
            &ElasticConfig {
                m_bins: cfg.m_bins,
                margin_t: cfg.margin_t,
                mode: cfg.mode,
                n_instances: 2,
                residual: cfg.pg_residual,
                policy: cfg.capacity_policy,
                latency_cap_sw: f64::INFINITY,
            },
        );
        let want = lut.entries[0];

        let fleet = FleetServing::start(cfg, "sim-no-artifacts".into()).unwrap();
        clock.sleep(Duration::from_millis(100));
        let stats = fleet.stats();
        assert_eq!(stats.per_group[0].epochs, 3, "CC epochs at 30/60/90 virtual ms");
        let g = &stats.per_group[0];
        let mv = |v: f64| volts_to_mv(v) as f64 / 1000.0;
        assert!(
            (g.vcore_now - mv(want.point.vcore)).abs() < 1e-9,
            "vcore gauge {} vs LUT {}",
            g.vcore_now,
            want.point.vcore
        );
        assert!(
            (g.vbram_now - mv(want.point.vbram)).abs() < 1e-9,
            "vbram gauge {} vs LUT {}",
            g.vbram_now,
            want.point.vbram
        );
        assert!((g.freq_ratio_now - want.freq_ratio).abs() < 1e-12);
        assert_eq!(g.active_now, want.n_active);
        // Static configuration: the new prediction surface reports the
        // fixed margin and the Markov predictor, in stats and gauges.
        assert!((g.margin_now - 0.05).abs() < 1e-12, "margin {}", g.margin_now);
        assert_eq!(g.predictor_now, "markov");
        // The legacy un-namespaced gauge is the 1-node back-compat
        // alias; the canonical name is namespaced by hosting node.
        assert!(
            (fleet.registry().gauge("tabla.margin_now").get() - 0.05).abs() < 1e-12,
            "margin gauge must be published under the 1-node alias"
        );
        assert!(
            (fleet.registry().gauge("node0.tabla.margin_now").get() - 0.05).abs() < 1e-12,
            "margin gauge must be published under the node namespace"
        );
        assert_eq!(
            fleet.registry().gauge("tabla.predictor_now").get(),
            crate::markov::PredictorKind::index_of_name("markov") as f64
        );
        assert_eq!(g.node_now, "node0");
        fleet.shutdown().unwrap();
    }

    #[test]
    fn ensemble_gauge_reports_the_active_member_never_ensemble() {
        // Regression (ISSUE 5 satellite): the live path used to seed the
        // predictor_now index from the configured kind, so stats read
        // before the first CC epoch reported the literal "ensemble"
        // where the offline path reports the active member.
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _driver = ActorScope::enter(&clock, "test-driver");
        let cfg = FleetServingConfig {
            groups: vec![group("tabla", 1.0, 2)],
            epoch: Duration::from_millis(20),
            warmup_epochs: 0,
            selector_via_pjrt: false,
            predictor: PredictorKind::Ensemble,
            clock: clock.clone(),
            ..Default::default()
        };
        let fleet = FleetServing::start(cfg, "sim-no-artifacts".into()).unwrap();
        // Before the first CC epoch: the startup member, not "ensemble".
        assert_eq!(fleet.stats().per_group[0].predictor_now, "markov");
        clock.sleep(Duration::from_millis(100));
        let now = fleet.stats().per_group[0].predictor_now;
        assert_ne!(now, "ensemble", "the gauge must always name a member");
        assert!(
            crate::markov::PREDICTOR_NAMES[1..].contains(&now),
            "unknown member {now}"
        );
        // The registry gauge publishes the member's index table entry.
        assert_eq!(
            fleet.registry().gauge("tabla.predictor_now").get(),
            PredictorKind::index_of_name(now) as f64
        );
        fleet.shutdown().unwrap();
    }

    #[test]
    fn start_validates_group_shares() {
        let cfg = FleetServingConfig {
            groups: vec![group("tabla", 0.5, 1)],
            ..Default::default()
        };
        assert!(FleetServing::start(cfg, "artifacts".into()).is_err());
        let cfg = FleetServingConfig { groups: vec![], ..Default::default() };
        assert!(FleetServing::start(cfg, "artifacts".into()).is_err());
        let cfg = FleetServingConfig {
            groups: vec![group("not-a-benchmark", 1.0, 1)],
            ..Default::default()
        };
        assert!(FleetServing::start(cfg, "artifacts".into()).is_err());
    }

    #[test]
    fn start_validates_fault_plan_and_qos_tiers() {
        // A board failure naming a shard outside the group's layout must
        // be refused at start, not discovered mid-run.
        let cfg = FleetServingConfig {
            faults: Arc::new(FaultPlan {
                board_failures: vec![crate::workload::BoardFailure {
                    group: 0,
                    shard: 5,
                    fail_epoch: 1,
                    recover_epoch: 2,
                }],
                ..Default::default()
            }),
            ..Default::default()
        };
        assert!(FleetServing::start(cfg, "artifacts".into()).is_err());
        let cfg = FleetServingConfig {
            faults: Arc::new(FaultPlan {
                stragglers: vec![crate::workload::StragglerWindow {
                    group: 3,
                    shard: 0,
                    from_epoch: 1,
                    until_epoch: 2,
                    slowdown: 2.0,
                }],
                ..Default::default()
            }),
            ..Default::default()
        };
        assert!(FleetServing::start(cfg, "artifacts".into()).is_err());
        let cfg = FleetServingConfig {
            groups: vec![GroupConfig {
                benchmark: "tabla".into(),
                share: 1.0,
                n_instances: 2,
                qos_target: Some(1.5),
            }],
            ..Default::default()
        };
        assert!(FleetServing::start(cfg, "artifacts".into()).is_err());
    }

    #[test]
    fn failed_board_is_gated_drained_and_recovers_without_dropping_work() {
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _driver = ActorScope::enter(&clock, "test-driver");
        let faults = Arc::new(FaultPlan {
            board_failures: vec![crate::workload::BoardFailure {
                group: 0,
                shard: 1,
                fail_epoch: 1,
                recover_epoch: 3,
            }],
            ..Default::default()
        });
        let cfg = FleetServingConfig {
            groups: vec![group("tabla", 1.0, 2)],
            epoch: Duration::from_millis(20),
            warmup_epochs: 0,
            selector_via_pjrt: false,
            faults,
            clock: clock.clone(),
            ..Default::default()
        };
        let fleet = FleetServing::start(cfg, "sim-no-artifacts".into()).unwrap();
        let in_dim = fleet.in_dim(0);
        for step in 0..5 {
            for _ in 0..8 {
                let _ = fleet.submit(0, vec![0.1; in_dim]);
            }
            clock.sleep(Duration::from_millis(20));
            if step == 1 {
                // Inside the failure window the downed shard is flagged
                // *and* gated, so dispatch, stealing and its worker all
                // avoid it while the CC re-dispatches its backlog.
                let shard = &fleet.nodes[0].slices[0].shards[1];
                assert!(shard.is_failed());
                assert!(shard.is_gated());
                assert_eq!(fleet.stats().per_group[0].failed_boards_now, 1);
            }
        }
        clock.sleep(Duration::from_millis(60));
        let report = fleet.shutdown().unwrap();
        let g = &report.stats.per_group[0];
        assert_eq!(
            g.admitted,
            g.completed + g.failed,
            "failover must uphold the drain invariant"
        );
        let recs = &report.epoch_records[0];
        assert_eq!(recs[0].n_failed, 0, "epoch 0 is served before any CC pass");
        assert!(
            recs.iter().any(|r| r.n_failed == 1),
            "the failure window must appear in the trace"
        );
        assert!(
            recs.iter().all(|r| r.slow_factor == 1.0),
            "no straggler windows in this plan"
        );
        let last = recs.last().unwrap();
        assert_eq!(last.n_failed, 0, "the board recovers before shutdown");
    }

    #[test]
    fn straggler_window_scales_capacity_and_preserves_conservation() {
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _driver = ActorScope::enter(&clock, "test-driver");
        let faults = Arc::new(FaultPlan {
            stragglers: vec![crate::workload::StragglerWindow {
                group: 0,
                shard: 0,
                from_epoch: 1,
                until_epoch: 3,
                slowdown: 2.0,
            }],
            ..Default::default()
        });
        let cfg = FleetServingConfig {
            groups: vec![group("tabla", 1.0, 2)],
            epoch: Duration::from_millis(20),
            warmup_epochs: 0,
            selector_via_pjrt: false,
            faults,
            clock: clock.clone(),
            ..Default::default()
        };
        let fleet = FleetServing::start(cfg, "sim-no-artifacts".into()).unwrap();
        let in_dim = fleet.in_dim(0);
        for _ in 0..5 {
            for _ in 0..4 {
                let _ = fleet.submit(0, vec![0.1; in_dim]);
            }
            clock.sleep(Duration::from_millis(20));
        }
        clock.sleep(Duration::from_millis(60));
        let report = fleet.shutdown().unwrap();
        let g = &report.stats.per_group[0];
        assert_eq!(g.admitted, g.completed + g.failed);
        let recs = &report.epoch_records[0];
        assert!(
            recs.iter().any(|r| r.slow_factor < 1.0),
            "the straggler window must shrink the modeled capacity"
        );
        assert!(recs.iter().all(|r| r.slow_factor > 0.0 && r.slow_factor <= 1.0));
        assert!(recs.iter().all(|r| r.n_failed == 0));
    }

    #[test]
    fn two_node_fleet_migrates_on_script_and_conserves_work() {
        // A 2-node fleet hosting one group on node0; a scripted move at
        // epoch 1 hands it to node1. Placement must follow, both nodes'
        // namespaced gauges must exist (the collision the namespacing
        // fixes), and no admitted request may be dropped.
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let _driver = ActorScope::enter(&clock, "test-driver");
        let cfg = FleetServingConfig {
            groups: vec![group("tabla", 1.0, 2)],
            epoch: Duration::from_millis(20),
            warmup_epochs: 0,
            selector_via_pjrt: false,
            nodes: 2,
            migrations: Arc::new(MigrationPlan {
                moves: vec![super::super::topology::ScriptedMigration {
                    epoch: 1,
                    group: 0,
                    from: 0,
                    to: 1,
                }],
            }),
            clock: clock.clone(),
            ..Default::default()
        };
        let fleet = FleetServing::start(cfg, "sim-no-artifacts".into()).unwrap();
        assert_eq!(fleet.n_nodes(), 2);
        assert_eq!(fleet.stats().per_group[0].node_now, "node0");
        let in_dim = fleet.in_dim(0);
        for _ in 0..6 {
            for _ in 0..8 {
                let _ = fleet.submit(0, vec![0.1; in_dim]);
            }
            clock.sleep(Duration::from_millis(20));
        }
        clock.sleep(Duration::from_millis(60));
        let snap = fleet.topology_snapshot();
        assert_eq!(snap.groups[0].hosted_on, vec!["node1".to_string()]);
        assert!(snap.version >= 1, "the move must bump the topology version");
        // Both hosts published under their own namespace — the collision
        // the `{node}.{group}.*` scheme fixes — and the un-namespaced
        // alias stays reserved for 1-node fleets.
        let names: Vec<String> = fleet
            .registry()
            .snapshot()
            .into_iter()
            .map(|(name, _)| name)
            .collect();
        assert!(names.iter().any(|n| n == "node0.tabla.margin_now"), "{names:?}");
        assert!(names.iter().any(|n| n == "node1.tabla.margin_now"), "{names:?}");
        assert!(
            !names.iter().any(|n| n == "tabla.margin_now"),
            "multi-node fleets must not publish the ambiguous alias"
        );
        let report = fleet.shutdown().unwrap();
        let g = &report.stats.per_group[0];
        assert_eq!(g.node_now, "node1");
        assert_eq!(g.migrated, 1);
        assert_eq!(
            g.admitted,
            g.completed + g.failed,
            "migration must uphold the drain invariant"
        );
        assert!(
            !report.epoch_records[0].is_empty(),
            "the epoch trace must travel with the controller"
        );
    }
}
