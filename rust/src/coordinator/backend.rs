//! Inference backends for the serving workers (DESIGN.md S11.4).
//!
//! Each worker executes request batches through an [`InferenceBackend`]:
//!
//! * [`InferenceBackend::Pjrt`] — the AOT-compiled JAX/Pallas artifact via
//!   the PJRT client (`runtime::DnnClient`), real numerics;
//! * [`InferenceBackend::Native`] — a deterministic pure-Rust MLP with the
//!   same batch/in/out geometry as the artifact. Used automatically when
//!   `artifacts/` or the PJRT runtime is unavailable so the whole serving
//!   stack (shards, stealing, DVFS epochs, fleet reports) stays exercisable
//!   in any environment.
//!
//! The fallback is per-worker and logged once in the group stats
//! (`backend` field); numbers produced by the native backend are *not*
//! golden-checked model outputs, only a stand-in compute load.

use std::path::Path;

use anyhow::Result;

use crate::runtime::{DnnClient, Engine};
use crate::util::prng::Rng;

/// (in_dim, out_dim) per benchmark variant; mirrors the python layer's
/// `DNN_VARIANTS` first/last dims (python/compile/model.py).
///
/// Synthetic scale-sweep tenants are named `{base}@{suffix}` (group names
/// must be unique but the five Table-1 designs are the only real
/// artifacts), so geometry keys on the base variant before the `@`.
pub fn variant_dims(variant: &str) -> (usize, usize) {
    let variant = variant.split('@').next().unwrap_or(variant);
    match variant {
        "tabla" => (128, 64),
        "dnnweaver" => (256, 64),
        "diannao" => (512, 64),
        "stripes" => (1024, 64),
        "proteus" => (512, 64),
        _ => (128, 64),
    }
}

/// Requests per inference dispatch, matching the artifact batch
/// (python/compile/model.py `DNN_BATCH`).
pub const NATIVE_BATCH: usize = 16;

const NATIVE_HIDDEN: usize = 64;

fn variant_seed(variant: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in variant.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Deterministic pure-Rust MLP: `y = relu(x W1 + b1) W2 + b2`, He-style
/// seeded weights. Geometry matches the served artifact so payload sizes
/// and batch formation behave identically to the PJRT path.
pub struct NativeDnn {
    /// Benchmark variant this model stands in for.
    pub variant: String,
    /// Requests per inference dispatch.
    pub batch: usize,
    /// Input feature width.
    pub in_dim: usize,
    /// Output width (logits).
    pub out_dim: usize,
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
}

impl NativeDnn {
    /// Build the fallback model for a variant (deterministic per variant).
    pub fn new(variant: &str) -> Self {
        let (in_dim, out_dim) = variant_dims(variant);
        let mut rng = Rng::new(variant_seed(variant));
        let scale1 = (2.0 / in_dim as f64).sqrt();
        let scale2 = (2.0 / NATIVE_HIDDEN as f64).sqrt();
        let w1 = (0..in_dim * NATIVE_HIDDEN)
            .map(|_| (rng.normal() * scale1) as f32)
            .collect();
        let w2 = (0..NATIVE_HIDDEN * out_dim)
            .map(|_| (rng.normal() * scale2) as f32)
            .collect();
        NativeDnn {
            variant: variant.to_string(),
            batch: NATIVE_BATCH,
            in_dim,
            out_dim,
            w1,
            b1: vec![0.0; NATIVE_HIDDEN],
            w2,
            b2: vec![0.0; out_dim],
        }
    }

    /// Run one batch (`x` is `batch × in_dim`, row-major).
    pub fn infer(&self, x: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            x.len() == self.batch * self.in_dim,
            "native dnn_{}: expected {}x{} input, got {} floats",
            self.variant,
            self.batch,
            self.in_dim,
            x.len()
        );
        let mut h = vec![0.0f32; self.batch * NATIVE_HIDDEN];
        for r in 0..self.batch {
            let xr = &x[r * self.in_dim..(r + 1) * self.in_dim];
            let hr = &mut h[r * NATIVE_HIDDEN..(r + 1) * NATIVE_HIDDEN];
            for (k, &xv) in xr.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &self.w1[k * NATIVE_HIDDEN..(k + 1) * NATIVE_HIDDEN];
                for (j, hv) in hr.iter_mut().enumerate() {
                    *hv += xv * wrow[j];
                }
            }
            for (j, hv) in hr.iter_mut().enumerate() {
                *hv = (*hv + self.b1[j]).max(0.0);
            }
        }
        let mut y = vec![0.0f32; self.batch * self.out_dim];
        for r in 0..self.batch {
            let hr = &h[r * NATIVE_HIDDEN..(r + 1) * NATIVE_HIDDEN];
            let yr = &mut y[r * self.out_dim..(r + 1) * self.out_dim];
            for (k, &hv) in hr.iter().enumerate() {
                if hv == 0.0 {
                    continue;
                }
                let wrow = &self.w2[k * self.out_dim..(k + 1) * self.out_dim];
                for (j, yv) in yr.iter_mut().enumerate() {
                    *yv += hv * wrow[j];
                }
            }
            for (j, yv) in yr.iter_mut().enumerate() {
                *yv += self.b2[j];
            }
        }
        Ok(y)
    }
}

/// A worker's inference engine: real PJRT artifact or native fallback.
pub enum InferenceBackend {
    /// AOT artifact executed through the PJRT client.
    Pjrt(DnnClient),
    /// Pure-Rust stand-in model (no artifacts / no PJRT required).
    Native(NativeDnn),
}

impl InferenceBackend {
    /// Open the best available backend for `variant`: PJRT when the
    /// artifacts directory and runtime work, native otherwise.
    pub fn open(artifacts_dir: &Path, variant: &str) -> InferenceBackend {
        match Engine::open(artifacts_dir)
            .and_then(|engine| DnnClient::new(&engine, variant))
        {
            Ok(client) => InferenceBackend::Pjrt(client),
            Err(_) => InferenceBackend::Native(NativeDnn::new(variant)),
        }
    }

    /// Short backend tag for stats/reports.
    pub fn name(&self) -> &'static str {
        match self {
            InferenceBackend::Pjrt(_) => "pjrt",
            InferenceBackend::Native(_) => "native",
        }
    }

    /// Requests per inference dispatch.
    pub fn batch(&self) -> usize {
        match self {
            InferenceBackend::Pjrt(c) => c.batch,
            InferenceBackend::Native(n) => n.batch,
        }
    }

    /// Input feature width.
    pub fn in_dim(&self) -> usize {
        match self {
            InferenceBackend::Pjrt(c) => c.in_dim,
            InferenceBackend::Native(n) => n.in_dim,
        }
    }

    /// Output width.
    pub fn out_dim(&self) -> usize {
        match self {
            InferenceBackend::Pjrt(c) => c.out_dim,
            InferenceBackend::Native(n) => n.out_dim,
        }
    }

    /// Run one batch (`x` is `batch × in_dim`, row-major).
    pub fn infer(&self, x: &[f32]) -> Result<Vec<f32>> {
        match self {
            InferenceBackend::Pjrt(c) => c.infer(x),
            InferenceBackend::Native(n) => n.infer(x),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_is_deterministic_per_variant() {
        let a = NativeDnn::new("tabla");
        let b = NativeDnn::new("tabla");
        let x: Vec<f32> = (0..a.batch * a.in_dim).map(|i| (i % 7) as f32 * 0.1).collect();
        assert_eq!(a.infer(&x).unwrap(), b.infer(&x).unwrap());
        let c = NativeDnn::new("diannao");
        assert_eq!(c.in_dim, 512);
        assert_ne!(a.w1, c.w1[..a.w1.len().min(c.w1.len())].to_vec());
    }

    #[test]
    fn native_backend_validates_shape() {
        let m = NativeDnn::new("tabla");
        assert!(m.infer(&[0.0; 3]).is_err());
        let y = m.infer(&vec![0.5; m.batch * m.in_dim]).unwrap();
        assert_eq!(y.len(), m.batch * m.out_dim);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn open_falls_back_to_native_without_artifacts() {
        let b = InferenceBackend::open(Path::new("/nonexistent-artifacts"), "tabla");
        assert_eq!(b.name(), "native");
        assert_eq!(b.batch(), NATIVE_BATCH);
        assert_eq!(b.in_dim(), 128);
        assert_eq!(b.out_dim(), 64);
    }

    #[test]
    fn variant_dims_cover_table1() {
        for v in ["tabla", "dnnweaver", "diannao", "stripes", "proteus"] {
            let (i, o) = variant_dims(v);
            assert!(i >= 64 && o == 64, "{v}");
        }
        assert_eq!(variant_dims("unknown"), (128, 64));
        // Synthetic scale-sweep tenants key geometry on their base design.
        assert_eq!(variant_dims("stripes@0042"), variant_dims("stripes"));
    }
}
