//! Cross-node request routing + rebalancing policy (DESIGN.md S21).
//!
//! The router is the thin top layer of the fleet-of-fleets split: given a
//! tenant's group index it picks the *node* whose slice receives the
//! submit, reading placement lock-free from the
//! [`TopologyStore`](super::topology::TopologyStore)'s hosting-mask
//! mirrors. Within the chosen node, shard selection stays the node's
//! business ([`place_request`](super::node::place_request)) and work
//! stealing never crosses a node boundary.
//!
//! The canonical topologies host each group on exactly one node, so the
//! hot path is a single mask read + `trailing_zeros`. Should a future
//! layout set several hosting bits, the router degrades to least-loaded
//! among the hosting nodes (queue-depth sum over the group's slice, ties
//! to the lowest node id) — the same policy the in-node dispatcher uses
//! one level down.
//!
//! [`RebalanceConfig`] parameterizes the opt-in saturation rebalancer
//! that runs inside each node's CC (`coordinator::node`): a group whose
//! modeled backlog stays at or above `min_backlog` for `sustain`
//! consecutive epochs is migrated to the node currently hosting the
//! fewest worker instances. It defaults to off (`None` in
//! [`FleetServingConfig`](super::FleetServingConfig)) so every legacy
//! single-node run and every equivalence golden stays bit-identical.

use crate::sync::Arc;

use super::node::NodeShared;
use super::topology::TopologyStore;

/// When the opt-in rebalancer migrates a group off a saturated node.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RebalanceConfig {
    /// Modeled-backlog threshold (in epochs of nominal capacity, the
    /// same unit as `max_backlog_steps`) at or above which an epoch
    /// counts toward saturation.
    pub min_backlog: f64,
    /// Consecutive over-threshold epochs before the group migrates —
    /// hysteresis so one flash-crowd epoch does not bounce placements.
    pub sustain: usize,
}

impl Default for RebalanceConfig {
    fn default() -> Self {
        RebalanceConfig { min_backlog: 0.5, sustain: 3 }
    }
}

/// Routes submits to the hosting node's slice.
pub(super) struct Router {
    store: Arc<TopologyStore>,
    nodes: Vec<Arc<NodeShared>>,
}

impl Router {
    /// A router over the fleet's nodes and its topology store.
    pub(super) fn new(store: Arc<TopologyStore>, nodes: Vec<Arc<NodeShared>>) -> Router {
        Router { store, nodes }
    }

    /// Node id whose slice should receive a submit for group `gi`:
    /// lock-free single-host fast path, least-loaded among hosting nodes
    /// otherwise (ties to the lowest id).
    pub(super) fn route(&self, gi: usize) -> usize {
        let mask = self.store.hosting_mask(gi);
        if mask.count_ones() == 1 {
            return mask.trailing_zeros() as usize;
        }
        let mut best: Option<(usize, usize)> = None; // (depth, node id)
        for (id, node) in self.nodes.iter().enumerate() {
            if mask & (1u64 << id) == 0 {
                continue;
            }
            let depth = node.slices[gi].depth();
            if best.map_or(true, |(d, _)| depth < d) {
                best = Some((depth, id));
            }
        }
        // A group is hosted somewhere by construction (validated at
        // start, preserved by migrate); the fallback covers a torn
        // wall-clock read mid-migration, where node 0 merely queues the
        // request until the next drain.
        best.map(|(_, id)| id).unwrap_or(0)
    }
}

/// Migration destination for a group leaving `exclude`: the other node
/// hosting the fewest worker instances (ties to the lowest id). `None`
/// on a 1-node fleet.
pub(super) fn pick_migration_target(store: &TopologyStore, exclude: usize) -> Option<usize> {
    store.with(|t| {
        let mut best: Option<(usize, usize)> = None; // (instances, node id)
        for id in 0..t.nodes().len() {
            if id == exclude {
                continue;
            }
            let load = t.hosted_instances(id);
            if best.map_or(true, |(l, _)| load < l) {
                best = Some((load, id));
            }
        }
        best.map(|(_, id)| id)
    })
}
