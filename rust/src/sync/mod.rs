//! Loom-switchable synchronization primitives (DESIGN.md S23).
//!
//! Every module on the sim-replay-critical concurrency path
//! (`coordinator/`, `clock/`, `metrics/`) imports its atomics, locks and
//! `UnsafeCell` through this shim instead of `std` — enforced statically by
//! `tools/detlint` rule `std-sync-bypass`. In a normal build the re-exports
//! *are* the `std` types (zero cost, zero behavior change); under
//! `RUSTFLAGS="--cfg loom"` they switch to the loom model checker's
//! instrumented equivalents so `tests/loom_models.rs` can exhaustively
//! explore every interleaving of the lock-free core (the Vyukov ring in
//! `coordinator::shard`, the `WaitSlot` generation protocol, the
//! `TopologyStore` mask publication).
//!
//! Two deliberate deviations from a 1:1 swap:
//!
//! * [`Arc`] is re-exported from `std` in **both** modes. The models never
//!   assert on `Arc` internals (loom's own `Arc` adds only leak
//!   accounting), and `std::sync::Arc` supports the unsized coercion to
//!   `Arc<dyn Clock>` that the serving path relies on, which an
//!   instrumented replacement type cannot provide on stable Rust.
//! * [`cell::UnsafeCell`] is a thin wrapper exposing loom's closure-based
//!   `with`/`with_mut` accessors in both modes, so the unsafe slot code in
//!   `coordinator::shard` is written once and gets loom's concurrent-access
//!   detection for free under `cfg(loom)`.

pub use std::sync::Arc;

#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
#[cfg(loom)]
pub use loom::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};

/// Atomic integer/bool types and memory orderings, switched between
/// `std::sync::atomic` and `loom::sync::atomic` by `cfg(loom)`.
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{fence, AtomicBool, AtomicU64, AtomicUsize, Ordering};

    #[cfg(loom)]
    pub use loom::sync::atomic::{fence, AtomicBool, AtomicU64, AtomicUsize, Ordering};
}

/// Interior-mutability cell with loom's closure-based access protocol.
pub mod cell {
    #[cfg(loom)]
    pub use loom::cell::UnsafeCell;

    /// `std::cell::UnsafeCell` behind loom's `with`/`with_mut` API.
    ///
    /// The closures receive the raw pointer; dereferencing it is still
    /// `unsafe` and every call site must carry a `// SAFETY:` comment
    /// (audited in `coordinator::shard`, see DESIGN.md S23). Under
    /// `cfg(loom)` the loom version of this type additionally panics the
    /// model when two threads' access windows overlap, turning a wrong
    /// SAFETY argument into a deterministic test failure.
    #[cfg(not(loom))]
    #[derive(Debug)]
    pub struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

    #[cfg(not(loom))]
    impl<T> UnsafeCell<T> {
        /// Wrap `value` in a cell.
        pub fn new(value: T) -> Self {
            UnsafeCell(std::cell::UnsafeCell::new(value))
        }

        /// Run `f` with a shared raw pointer to the contents.
        pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
            f(self.0.get())
        }

        /// Run `f` with an exclusive raw pointer to the contents.
        pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
            f(self.0.get())
        }
    }
}

/// Spin-loop hint; under loom a spin must yield so the cooperative
/// scheduler can run the thread the spinner is waiting on.
pub mod hint {
    #[cfg(not(loom))]
    pub use std::hint::spin_loop;

    /// Loom build: a spin is a scheduling point, not a CPU hint.
    #[cfg(loom)]
    pub fn spin_loop() {
        loom::thread::yield_now();
    }
}
